"""Sharded checkpointing: npz-per-host-shard + atomic JSON manifest.

Layout of one checkpoint::

    <dir>/step_000123/
        shard_00000.npz          # this host's leaves (flattened key -> array)
        MANIFEST.json            # written LAST, atomically (tmp+rename):
                                 # a checkpoint without a manifest is invalid

Crash-consistency: the manifest rename is the commit point.  A job killed
mid-write leaves a step directory without MANIFEST.json, which restore
ignores and ``gc_incomplete`` removes.

Durability: rename alone only orders the commit against *processes* —
against power loss the shard bytes, the manifest bytes, AND the parent
directory entries must each reach stable storage, so every save fsyncs
the tmp file before its rename and the step directory (plus the root,
which holds the step dir's own entry) after the manifest rename.

Restore *reshards*: leaves are loaded on host and ``jax.device_put`` onto the
target shardings — which may belong to a different mesh than the one that
saved (elastic rescale).  Async save snapshots to host memory synchronously
(cheap) and writes on a background thread (the TPU analogue: device->host DMA
then async filesystem write).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import numpy as np

_MANIFEST = "MANIFEST.json"
_STEP_RE = re.compile(r"^step_(\d{9})$")


def _jax():
    # jax only backs the pytree save/restore path; the numpy-only
    # save_arrays/load_arrays path (coherence snapshots, nojax CI leg)
    # must import this module without it
    import jax
    return jax


def _flat(tree) -> dict:
    jax = _jax()
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(p): v for p, v in leaves}


def _fsync_dir(path: Path):
    """fsync a *directory*: renames inside it are only durable once the
    directory's own entry table reaches disk (POSIX leaves them volatile
    until then — a power-loss after rename can otherwise resurrect the
    tmp name or lose the committed one)."""
    fd = os.open(path, getattr(os, "O_DIRECTORY", os.O_RDONLY))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write_committed(d: Path, host_flat: Dict[str, np.ndarray],
                     manifest: dict, host: int):
    """The shared durable-commit protocol for both save paths: fsync'd
    tmp-write + rename for the shard, fsync'd tmp-write + rename for the
    manifest (the commit point), then fsync the step dir (persists both
    renames) and its parent (persists the step dir's creation)."""
    shard = d / f"shard_{host:05d}.npz"
    tmp = d / f".shard_{host:05d}.tmp.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **host_flat)
        f.flush()
        os.fsync(f.fileno())
    tmp.rename(shard)
    mtmp = d / ".manifest.tmp"
    with open(mtmp, "w") as f:
        f.write(json.dumps(manifest, indent=1))
        f.flush()
        os.fsync(f.fileno())
    mtmp.rename(d / _MANIFEST)     # commit point
    _fsync_dir(d)                  # makes both renames durable
    _fsync_dir(d.parent)           # makes the step dir itself durable


def _step_dir(root: Path, step: int) -> Path:
    return Path(root) / f"step_{step:09d}"


def _step_dirs(root: Path):
    """(step, path) for every *conforming* ``step_NNNNNNNNN`` directory.
    Stray entries (editor backups, ``.nfs*`` debris, ``step_tmp`` …) are
    ignored — they used to crash ``latest_step``/``_rotate`` with
    ``ValueError`` on the int parse."""
    out = []
    for p in root.glob("step_*"):
        m = _STEP_RE.match(p.name)
        if m and p.is_dir():
            out.append((int(m.group(1)), p))
    return out


def save_checkpoint(root, step: int, tree, *, blocking: bool = True,
                    extra: Optional[dict] = None, host: int = 0
                    ) -> "threading.Thread | None":
    """Snapshot ``tree`` (host transfer happens now); write shard + manifest
    (now, or on a background thread when ``blocking=False``)."""
    root = Path(root)
    d = _step_dir(root, step)
    d.mkdir(parents=True, exist_ok=True)
    flat = _flat(tree)
    # snapshot: device -> host, synchronous (correctness barrier); the
    # filesystem write is what can be async
    host_flat = {k: np.asarray(v) for k, v in flat.items()}
    spec = {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in host_flat.items()}

    def _write():
        manifest = {
            "step": step,
            "time": time.time(),
            "n_hosts": 1,
            "leaves": spec,
            "extra": extra or {},
        }
        _write_committed(d, host_flat, manifest, host)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(root) -> Optional[int]:
    root = Path(root)
    if not root.exists():
        return None
    steps = [s for s, p in _step_dirs(root) if (p / _MANIFEST).exists()]
    return max(steps) if steps else None


def restore_checkpoint(root, step: int, template, *, shardings=None) -> Any:
    """Load step's arrays into ``template``'s structure.  ``shardings``
    (same structure) reshards onto a possibly-different mesh."""
    jax = _jax()
    d = _step_dir(Path(root), step)
    manifest = json.loads((d / _MANIFEST).read_text())
    data = {}
    for shard in sorted(d.glob("shard_*.npz")):
        with np.load(shard) as z:
            data.update({k: z[k] for k in z.files})
    missing = set(manifest["leaves"]) - set(data)
    assert not missing, f"checkpoint missing leaves: {sorted(missing)[:5]}"

    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    sh_leaves = (jax.tree_util.tree_leaves(shardings)
                 if shardings is not None else [None] * len(leaves))
    out = []
    for (path, tmpl), sh in zip(leaves, sh_leaves):
        key = jax.tree_util.keystr(path)
        arr = data[key]
        assert tuple(arr.shape) == tuple(tmpl.shape), (key, arr.shape,
                                                       tmpl.shape)
        arr = arr.astype(tmpl.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_extra(root, step: int) -> dict:
    d = _step_dir(Path(root), step)
    return json.loads((d / _MANIFEST).read_text())["extra"]


def save_arrays(root, step: int, arrays: Dict[str, np.ndarray], *,
                extra: Optional[dict] = None, host: int = 0):
    """Numpy-only checkpoint save — no jax, no pytree.  ``arrays`` is a
    flat name->ndarray dict (e.g. ``RegCScaleRuntime.snapshot()``
    output); ``extra`` carries the JSON-serializable meta.  Same on-disk
    layout and crash-consistency protocol as :func:`save_checkpoint`:
    tmp-write + rename per shard, manifest rename as the commit point —
    so ``latest_step``/``gc_incomplete``/``CheckpointManager`` rotation
    all apply unchanged."""
    d = _step_dir(Path(root), step)
    d.mkdir(parents=True, exist_ok=True)
    host_flat = {k: np.asarray(v) for k, v in arrays.items()}
    spec = {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in host_flat.items()}
    manifest = {"step": step, "time": time.time(), "n_hosts": 1,
                "leaves": spec, "extra": extra or {}}
    _write_committed(d, host_flat, manifest, host)


def load_arrays(root, step: int) -> "tuple[Dict[str, np.ndarray], dict]":
    """Numpy-only restore of a :func:`save_arrays` checkpoint: returns
    (arrays, extra)."""
    d = _step_dir(Path(root), step)
    manifest = json.loads((d / _MANIFEST).read_text())
    data: Dict[str, np.ndarray] = {}
    for shard in sorted(d.glob("shard_*.npz")):
        with np.load(shard) as z:
            data.update({k: z[k] for k in z.files})
    missing = set(manifest["leaves"]) - set(data)
    assert not missing, f"checkpoint missing leaves: {sorted(missing)[:5]}"
    return data, manifest["extra"]


def gc_incomplete(root):
    """Remove step dirs that never committed a manifest (crash debris).
    Only conforming ``step_NNNNNNNNN`` directories are candidates — a
    stray foreign entry is not ours to delete."""
    root = Path(root)
    if not root.exists():
        return
    for _s, p in _step_dirs(root):
        if not (p / _MANIFEST).exists():
            shutil.rmtree(p)


class CheckpointManager:
    """Keep-last-k rotation + async writes with at-most-one in flight."""

    def __init__(self, root, *, keep: int = 3, async_write: bool = True):
        self.root = Path(root)
        self.keep = keep
        self.async_write = async_write
        self._inflight: Optional[threading.Thread] = None
        gc_incomplete(self.root)

    def save(self, step: int, tree, *, extra: Optional[dict] = None):
        self.wait()
        self._inflight = save_checkpoint(
            self.root, step, tree, blocking=not self.async_write, extra=extra)
        self._rotate(pending=step)

    def save_arrays(self, step: int, arrays: Dict[str, np.ndarray], *,
                    extra: Optional[dict] = None):
        """Numpy-only flat-dict save (see module-level ``save_arrays``)
        with the manager's rotation and at-most-one-in-flight async
        discipline — no jax anywhere on this path."""
        self.wait()
        snap = {k: np.asarray(v).copy() for k, v in arrays.items()}
        if self.async_write:
            t = threading.Thread(target=save_arrays,
                                 args=(self.root, step, snap),
                                 kwargs={"extra": extra}, daemon=True)
            t.start()
            self._inflight = t
        else:
            save_arrays(self.root, step, snap, extra=extra)
        self._rotate(pending=step)

    def wait(self):
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None

    def _rotate(self, pending: Optional[int] = None):
        steps = sorted(s for s, p in _step_dirs(self.root)
                       if (p / _MANIFEST).exists())
        if pending is not None and pending not in steps:
            steps = sorted(steps + [pending])   # in-flight counts toward keep
        for s in steps[: max(0, len(steps) - self.keep)]:
            if s != pending:
                shutil.rmtree(_step_dir(self.root, s))

    def latest(self) -> Optional[int]:
        self.wait()
        return latest_step(self.root)

    def restore(self, step: int, template, *, shardings=None):
        self.wait()
        return restore_checkpoint(self.root, step, template,
                                  shardings=shardings)
