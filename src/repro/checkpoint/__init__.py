from repro.checkpoint.store import (
    CheckpointManager, gc_incomplete, latest_step, load_arrays,
    restore_checkpoint, save_arrays, save_checkpoint,
)

__all__ = ["CheckpointManager", "gc_incomplete", "latest_step",
           "load_arrays", "restore_checkpoint", "save_arrays",
           "save_checkpoint"]
