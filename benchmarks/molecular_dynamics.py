"""Molecular dynamics — paper Fig. 7 (strong-scaling speedup; the store-
instrumentation overhead is visible on `samhita` because the O(n^2/p) force
loop's stores are instrumented even though they're ordinary-region)."""
from __future__ import annotations

import argparse
import time

from benchmarks.common import (SteadyState, danger_fields, make_rt,
                               print_rows, traffic_fields, write_bench_json,
                               write_csv)
from repro.dsm.apps import molecular_dynamics

N_PARTICLES = 8192
CORES = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def _run(series: str, mode: str, p: int, n: int, iters: int,
         driver: str = "batched", **rt_kw):
    ss = SteadyState()
    t0 = time.perf_counter()
    rt = make_rt(series, p, **rt_kw)
    molecular_dynamics(rt, n, iters, mode=mode, driver=driver, on_iter=ss)
    return ss.per_iter(), rt, time.perf_counter() - t0


def spill(iters: int, driver: str, n: int):
    """MD under a cache smaller than the (small) position/force arrays:
    every worker re-reads ALL positions each step, so spill eviction and
    the unaligned force-row halos interact — the residual-replay regime
    (traffic bit-identical across drivers; recorded here)."""
    rows = []
    n_pages = -(-(n * 3) // 1024)
    for p in (16, 64, 256):
        t, rt, t_wall = _run("samhita", "reduction", p, n, iters, driver,
                             cache_pages=max(n_pages // 2, 4))
        rows.append({"figure": "fig7_md_spill", "series": "samhita_spill",
                     "p": p, "n_particles": n, "driver": driver,
                     "t_iter_s": round(t, 6),
                     "net_bytes": rt.traffic.total_bytes,
                     "t_model_s": round(rt.time, 6),
                     "t_wall_s": round(t_wall, 4),
                     **traffic_fields(rt), **danger_fields(rt)})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=6)
    ap.add_argument("--particles", type=int, default=N_PARTICLES)
    ap.add_argument("--spill", action="store_true",
                    help="run only the capacity-pressure (fig7_md_spill) "
                         "points — the CI bench-smoke subset")
    ap.add_argument("--driver", choices=["loop", "batched"],
                    default="batched",
                    help="SPMD phase driver: per-worker loop or phase_all")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write machine-readable rows here")
    args = ap.parse_args(argv)
    n = args.particles
    rows = []
    if not args.spill:
        t_ref, _, _ = _run("pthreads", "reduction", 1, n, args.iters,
                           args.driver)
        for p in CORES:
            for series, mode, tag in (
                    ("pthreads", "reduction", "pthreads"),
                    ("samhita", "lock", "samhita_lock"),
                    ("samhita", "reduction", "samhita_reduction"),
                    ("samhita_page", "lock", "samhita_page_lock"),
                    ("samhita_page", "reduction", "samhita_page_reduction")):
                if series == "pthreads" and p > 8:
                    continue
                t, rt, t_wall = _run(series, mode, p, n, args.iters,
                                     args.driver)
                rows.append({"figure": "fig7_md", "series": tag, "p": p,
                             "n_particles": n, "driver": args.driver,
                             "t_iter_s": round(t, 6),
                             "speedup": round(t_ref / t, 3),
                             "net_bytes": rt.traffic.total_bytes,
                             "t_model_s": round(rt.time, 6),
                             "t_wall_s": round(t_wall, 4),
                             **traffic_fields(rt)})
    # a --spill-only point set is partial: write_csv's clobber guard
    # redirects it to <name>.partial.csv instead of shadowing the
    # committed rows
    rows += spill(max(2, args.iters // 2), args.driver, n)
    write_csv("molecular_dynamics" if args.driver == "batched"
              else f"molecular_dynamics_{args.driver}", rows)
    if args.json:
        write_bench_json(args.json, rows)
    print_rows(rows)
    return rows


if __name__ == "__main__":
    main()
