"""RegC at the training layer (DESIGN.md §2.2): measure what the paper's
dichotomy buys in a distributed trainer.

Compares gradient-sync policies on an 8-way DP mesh (subprocess with 8 host
devices; the bench process itself keeps 1 device):

* lazy/object    — RegC: local accumulation, one fine-grained psum per
                   parameter at the step barrier
* lazy/bucket    — RegC with page-like bucketing
* eager/object   — RC baseline: sync at every microbatch 'release'
* lazy/int8_ring — beyond-paper compressed ring (the diff analogue)

Metric: per-device collective bytes + message count from the lowered HLO
(exact), plus measured CPU wall-time per step (indicative only).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from benchmarks.common import print_rows, write_csv

SCRIPT = r"""
import json, time
import jax, jax.numpy as jnp
from repro.configs import get_reduced
from repro.launch import hlo_analysis
from repro.models import model as M
from repro.optim.adamw import init_opt_state
from repro.regc_sync.policies import RegCSyncPolicy
from repro.train.train_step import TrainHParams, make_train_step_regc

cfg = get_reduced("internlm2-1.8b", n_periods=2)
from repro.compat import make_mesh
mesh = make_mesh((8,), ("data",))
params = M.init_model_params(cfg, jax.random.PRNGKey(0), jnp.float32)
opt = init_opt_state(params)
ks = jax.random.split(jax.random.PRNGKey(1), 2)
B, S = 16, 64
batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
         "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
step0 = jnp.zeros((), jnp.int32)

POLICIES = [
    ("lazy_object",  RegCSyncPolicy("lazy", "object"), 2),
    ("lazy_bucket",  RegCSyncPolicy("lazy", "bucket", 1 << 20), 2),
    ("eager_object", RegCSyncPolicy("eager", "object"), 2),
    ("int8_ring",    RegCSyncPolicy("lazy", "object", compression="int8_ring"), 2),
]
rows = []
for tag, pol, n_micro in POLICIES:
    hp = TrainHParams(remat=None, ce_chunk=32, n_micro=n_micro, sync=pol)
    fn = make_train_step_regc(cfg, hp, mesh, dp_axes=("data",))
    jfn = jax.jit(fn)
    lowered = jfn.lower(params, opt, batch, step0)
    st = hlo_analysis.analyze(lowered.compile().as_text())
    t0 = time.perf_counter()
    out = jfn(params, opt, batch, step0)
    jax.block_until_ready(out[2]["loss"])
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(3):
        out = jfn(params, opt, batch, step0)
    jax.block_until_ready(out[2]["loss"])
    t_step = (time.perf_counter() - t0) / 3
    rows.append({
        "policy": tag,
        "collective_bytes_per_dev": st.total_collective_bytes,
        "coll_msgs": sum(st.collective_count.values()),
        "ar_bytes": st.collective_bytes.get("all-reduce", 0.0),
        "permute_bytes": st.collective_bytes.get("collective-permute", 0.0),
        "loss": float(out[2]["loss"]),
        "wall_s_per_step": round(t_step, 4),
    })
print("JSON" + json.dumps(rows))
"""


def main(argv=None):
    # the trainer needs shard_map + host-device meshes; repro.compat shims
    # both back to jax 0.4.x, but anything older predates the experimental
    # shard_map API entirely — record a clear skip instead of a deep error
    import jax
    import re
    ver = tuple(int(m.group()) for m in
                (re.match(r"\d+", x) for x in jax.__version__.split(".")[:3])
                if m)
    if len(ver) == 3 and ver < (0, 4, 30):
        print(f"regc_training: jax {jax.__version__} < 0.4.30 lacks a "
              "usable shard_map; skipping", flush=True)
        return []
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    line = [l for l in out.stdout.splitlines() if l.startswith("JSON")][0]
    rows = json.loads(line[4:])
    for r in rows:
        r["figure"] = "regc_training"
    rows = [{"figure": r.pop("figure"), **r} for r in rows]
    write_csv("regc_training", rows)
    print_rows(rows)
    return rows


if __name__ == "__main__":
    main()
