"""KV-cache serving — the inference-traffic adversary (fig8_kv_serving).

``apps.kv_serving`` models a continuous-batching inference fleet as a
RegC program: workers are decode slots over a paged KV arena (6-page
slots: 96 KV rows x 64 words on 1024-word pages), a Zipf-skewed
multi-tenant request stream with bursty arrivals (burst size scales with
W so queueing pressure survives the core sweep), admission spans on one
hot lock, bulk prefill writes, and windowed decode reads + appended rows
— under a ``cache_pages`` budget (4) below a cold tenant's prompt
working set, so paged-attention eviction pressure drives the danger and
batched-eviction engine paths (asserted per point below).

Every point reports request-level p50/p99 latency and tokens/s — both
derived from MODELED clocks, so they are deterministic and bit-equal
across drivers/backends, but like ``t_model_s`` they are report-only
perf trajectory, NOT gated.  The gated fields are the exact ``tr_*``
traffic, the ``danger_*``/``span_*`` path counters, and the integer
``srv_*`` workload counters; ``benchmarks.compare`` diffs all of them
field-for-field.  When jax is present a ``pallas``-backend twin runs
in-bench, asserted traffic- AND clock-bit-equal: one live sample per
series by default (batched, W=16 — interpret-mode kernels cost minutes
per point on CPU), the full grid under ``BENCH_PALLAS_TWIN=1`` (run
once when the committed artifacts were produced).  The both-drivers
half of the contract is the committed loop/batched row pairs plus
``tests/test_kv_serving.py``.

The request stream is a pure function of (W, seed), NOT of ``--iters``
(accepted for harness uniformity), so every invocation regenerates the
identical committed point set — like the lock/recovery sections, a
focused run's CSVs are redirected by the CI serve job via ``BENCH_OUT``.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks.common import (danger_fields, make_rt, print_rows,
                               span_fields, traffic_fields,
                               write_bench_json, write_csv)
from repro.dsm.apps import kv_serving

CORES = (16, 64, 256)
REQ_PER_SLOT = 3
TOK_WORDS = 64          # one KV row (all layers' K+V for one token)
MAX_TOKENS = 96         # slot capacity -> 6 pages per slot
ATTN_WINDOW = 32        # trailing-window attention reads
CACHE_PAGES = 4         # below a cold prompt's pages: eviction regime
N_TENANTS = 16
SEED = 7


def serve_point(series: str, p: int, driver: str, *, backend="numpy"):
    rt = make_rt(series, p, cache_pages=CACHE_PAGES, backend=backend)
    t0 = time.perf_counter()
    rep = kv_serving(rt, REQ_PER_SLOT * p, tok_words=TOK_WORDS,
                     max_tokens=MAX_TOKENS, attn_window=ATTN_WINDOW,
                     n_tenants=N_TENANTS, burst_mean=max(2, p // 8),
                     gap_max=2, seed=SEED, driver=driver)
    return rt, rep, time.perf_counter() - t0


def serving(iters: int, driver: str, cores=CORES):
    from repro.kernels.protocol_sweep import HAVE_PALLAS
    rows = []
    for p in cores:
        for series in ("samhita", "samhita_page"):
            rt, rep, wall = serve_point(series, p, driver)
            # paged-attention pressure must actually fire, per point:
            # wide prefills cross the mid-op danger screen on the
            # vectorized path, and (batched driver) the sliding decode
            # windows keep batched eviction rounds live
            assert rt.stats["danger_vec_ops"] > 0, (series, p, driver)
            assert rt.stats["danger_scalar_ops"] == 0, (series, p, driver)
            assert rt.stats["span_all_calls"] > 0 or driver == "loop", \
                (series, p)
            if driver == "batched":
                assert rt.stats["evict_batch_rounds"] > 0, (series, p)
            if HAVE_PALLAS and (os.environ.get("BENCH_PALLAS_TWIN") == "1"
                                or (driver == "batched" and p == 16)):
                # both-backends half of the exactness contract, in-bench.
                # Interpret-mode kernels cost 24-130s per twin on CPU, so
                # the default run pins one live twin per series (batched,
                # W=16) and BENCH_PALLAS_TWIN=1 sweeps the full grid (all
                # points validated once when the artifacts were
                # committed); tests/test_kv_serving.py holds the
                # backend contract at app scale on every CI run.
                rt2, rep2, _ = serve_point(series, p, driver,
                                           backend="pallas")
                assert traffic_fields(rt2) == traffic_fields(rt), \
                    (series, p, driver, "pallas traffic drift")
                np.testing.assert_array_equal(
                    rt2.clock, rt.clock,
                    err_msg=f"pallas clock drift {series} W={p}")
                np.testing.assert_array_equal(rep2.latencies(),
                                              rep.latencies())
                # jit twin on the same live sample: the fused flush
                # chain must reproduce traffic/clocks/latencies exactly
                # AND actually dispatch — jit_dispatches == 0 would mean
                # the compiled tier silently degraded to numpy
                rt3, rep3, _ = serve_point(series, p, driver,
                                           backend="pallas-jit")
                assert traffic_fields(rt3) == traffic_fields(rt), \
                    (series, p, driver, "pallas-jit traffic drift")
                np.testing.assert_array_equal(
                    rt3.clock, rt.clock,
                    err_msg=f"pallas-jit clock drift {series} W={p}")
                np.testing.assert_array_equal(rep3.latencies(),
                                              rep.latencies())
                assert rt3.stats["jit_dispatches"] > 0, \
                    (series, p, driver, "jit twin never dispatched")
            lat = rep.latencies()
            rows.append({
                "figure": "fig8_kv_serving", "series": series, "p": p,
                "n": len(rep.requests), "driver": driver,
                "t_model_s": round(rt.time, 6),
                "t_wall_s": round(wall, 4),
                "net_bytes": rt.traffic.total_bytes,
                # request-level serving metrics (modeled, report-only)
                "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 6),
                "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 6),
                "tokens_per_s": round(rep.tokens_per_s(), 1),
                "req_per_s": round(len(lat) / rep.span_time, 1),
                # gated integer workload counters
                "srv_requests": len(lat),
                "srv_prefill_tok": rep.prefill_tokens,
                "srv_decode_tok": rep.decode_tokens,
                "srv_steps": rep.steps,
                "srv_admit_spans": rep.admit_spans,
                "srv_admitted": rep.admitted,
                "srv_idle_slot_steps": rep.idle_slot_steps,
                "srv_peak_queue": rep.peak_queue,
                "srv_evict_rounds": rt.stats["evict_batch_rounds"],
                **traffic_fields(rt), **danger_fields(rt),
                **span_fields(rt)})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=8,
                    help="accepted for harness uniformity; the request "
                         "stream is fixed per (W, seed) so the committed "
                         "point set never depends on it")
    ap.add_argument("--driver", choices=["loop", "batched"],
                    default="batched",
                    help="SPMD phase + span driver: per-worker loop or "
                         "phase_all/span_all")
    ap.add_argument("--smoke", action="store_true",
                    help="quick local subset (W <= 64).  Missing the "
                         "committed W=256 keys routes the output to "
                         "*.partial.csv, so the committed artifacts stay "
                         "untouched")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write machine-readable rows here")
    args = ap.parse_args(argv)
    rows = serving(args.iters, args.driver,
                   cores=CORES[:2] if args.smoke else CORES)
    write_csv("kv_serving" if args.driver == "batched"
              else f"kv_serving_{args.driver}", rows)
    if args.json:
        write_bench_json(args.json, rows)
    print_rows(rows)
    return rows


if __name__ == "__main__":
    main()
