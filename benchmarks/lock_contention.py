"""Lock contention — the span-engine bench (fig6_lock_contention).

The paper's programmability claim is that consistency regions
(lock-delimited spans) cost what they touch, not what the machine does to
serialize them; this section stresses exactly the part of our runtime
that makes that true at scale: the ``span_all`` pipelined span driver.
``apps.lock_contention`` runs, per iteration, a bulk ordinary phase (so
every span pass starts with real flush work to hoist) and two adversarial
span passes — W/n_locks-deep grant chains on ``n_locks`` disjoint striped
locks, then a W-deep chain on ONE hot lock.

Both samhita protocol series run at W = 16/64/256 on the selected driver;
rows carry the exact ``tr_*`` traffic fields (gated field-for-field by
``benchmarks.compare``) plus the span-engine path counters ``span_vec`` /
``span_serial`` proving the analytic group path — not the serial fallback
— absorbed the spans (also gated: a silent flip to the fallback keeps
traffic identical but is a perf regression).
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import (SteadyState, make_rt, print_rows,
                               span_fields, traffic_fields,
                               write_bench_json, write_csv)
from repro.dsm.apps import lock_contention

N_BASE = 1 << 20
CORES = (16, 64, 256)
N_LOCKS = 8


def contention(iters: int, driver: str, cores=CORES):
    rows = []
    for p in cores:
        for series in ("samhita", "samhita_page"):
            ss = SteadyState()
            t0 = time.perf_counter()
            rt = make_rt(series, p)
            lock_contention(rt, N_BASE, iters, n_locks=N_LOCKS, sweeps=2,
                            driver=driver, on_iter=ss)
            t_wall = time.perf_counter() - t0
            rows.append({"figure": "fig6_lock_contention", "series": series,
                         "p": p, "n": N_BASE, "driver": driver,
                         "t_iter_s": round(ss.per_iter(), 6),
                         "net_bytes": rt.traffic.total_bytes,
                         "t_model_s": round(rt.time, 6),
                         "t_wall_s": round(t_wall, 4),
                         **traffic_fields(rt), **span_fields(rt)})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--driver", choices=["loop", "batched"],
                    default="batched",
                    help="SPMD phase + span driver: per-worker loop or "
                         "phase_all/span_all")
    ap.add_argument("--smoke", action="store_true",
                    help="quick local subset (W <= 64).  Missing the "
                         "committed W=256 keys routes the output to "
                         "*.partial.csv, so the committed artifacts stay "
                         "untouched")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write machine-readable rows here")
    args = ap.parse_args(argv)
    rows = contention(args.iters, args.driver,
                      cores=CORES[:2] if args.smoke else CORES)
    write_csv("lock_contention" if args.driver == "batched"
              else f"lock_contention_{args.driver}", rows)
    if args.json:
        write_bench_json(args.json, rows)
    print_rows(rows)
    return rows


if __name__ == "__main__":
    main()
