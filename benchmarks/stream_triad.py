"""STREAM TRIAD — paper Figs. 2 (strong), 3 (weak), 4 (cache spill).

Metric: sustained bandwidth GB/s = 3n * 4 bytes / modeled-seconds-per-iter.
The paper runs 400 iterations with a barrier each; per-iteration traffic is
steady after the cold start, so we run fewer and report the steady-state
per-iteration time (asserted steady in tests/test_paper_claims.py).
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import (SERIES, SteadyState, danger_fields, make_rt,
                               print_rows, traffic_fields, write_bench_json,
                               write_csv)
from repro.dsm.apps import (stream_refetch, stream_spill, stream_triad,
                            triad_bytes_per_iter)

N_BASE = 16 << 20          # paper: n = 16M doubles-worth of fp32 words
CORES = (1, 2, 4, 8, 16, 32, 64, 128, 256)
# Fig-4 cache size: fits the small problem, spills at 2x (also imported
# by the tests that re-derive committed CSV points)
SPILL_CACHE_PAGES = 3 * (N_BASE // 1024) + 64


def spill_iters(iters: int) -> int:
    """Iteration rule for the spill section (shared with the no-drift
    tests so re-derivation always matches the harness)."""
    return max(4, iters // 2)


def bw_gbs(n: int, t_iter: float) -> float:
    return triad_bytes_per_iter(n) / t_iter / 1e9


def _point(figure: str, series: str, p: int, n: int, iters: int,
           driver: str = "batched", **rt_kw):
    ss = SteadyState()
    t0 = time.perf_counter()
    rt = make_rt(series if series in SERIES else "samhita", p, **rt_kw)
    stream_triad(rt, n, iters, driver=driver, on_iter=ss)
    t_wall = time.perf_counter() - t0
    return {"figure": figure, "series": series, "p": p, "n": n,
            "driver": driver,
            "t_iter_s": round(ss.per_iter(), 6),
            "bandwidth_GBs": round(bw_gbs(n, ss.per_iter()), 3),
            "net_bytes": rt.traffic.total_bytes,
            "t_model_s": round(rt.time, 6),
            "t_wall_s": round(t_wall, 4),
            **traffic_fields(rt)}


def strong(iters: int, driver: str):
    rows = []
    for p in CORES:
        for series in SERIES:
            if series == "pthreads" and p > 8:
                continue       # Pthreads exists only within one node
            rows.append(_point("fig2_strong", series, p, N_BASE, iters,
                               driver))
    return rows


def weak(iters: int, driver: str):
    rows = []
    for p in CORES:
        n = N_BASE * p
        for series in SERIES:
            if series == "pthreads" and p > 8:
                continue
            rows.append(_point("fig3_weak", series, p, n, iters, driver))
    return rows


def spill(iters: int, driver: str):
    """samhita only: per-worker problem 2x the local cache (Fig 4)."""
    rows = []
    cache_pages = SPILL_CACHE_PAGES
    for p in CORES:
        for scale, tag in ((1, "fits"), (2, "spills")):
            n = N_BASE * p * scale
            r = _point("fig4_spill", f"samhita_{tag}", p, n, iters, driver,
                       cache_pages=cache_pages)
            rows.append(r)
    rows += spill_heavy(iters, driver)
    return rows


def spill_heavy(iters: int, driver: str):
    """Rotating-block spill (``apps.stream_spill``): every pass shifts the
    block assignment, so each worker's dirty block lands inside its
    neighbours' reach — the batched driver's window-disjointness analysis
    routes the interacting workers through tick-ordered residual replay,
    whose per-worker ops hit the danger screen and resolve through the
    vectorized refetch schedule.  Plus the mid-op refetch torture
    (``apps.stream_refetch``): disjoint blocks with half-overlapping
    sliding windows, where EVERY op is danger-flagged and stays on the
    batched path.  Traffic stays bit-identical across drivers; the rows
    record the danger-path counters proving the vectorized schedule (not
    the scalar fallback) absorbed the pattern."""
    rows = []
    for p in (16, 64, 256):
        n = (1 << 17) * p              # 128 pages per worker
        cache_pages = (3 * (n // 1024)) // (2 * p)   # ~¾ of the 2-array set
        ss = SteadyState()
        t0 = time.perf_counter()
        rt = make_rt("samhita", p, cache_pages=cache_pages)
        stream_spill(rt, n, max(2, iters // 2), sweeps=2, driver=driver,
                     on_iter=ss)
        rows.append({"figure": "fig4_spill_heavy", "series": "samhita_rot",
                     "p": p, "n": n, "driver": driver,
                     "t_iter_s": round(ss.per_iter(), 6),
                     "net_bytes": rt.traffic.total_bytes,
                     "t_model_s": round(rt.time, 6),
                     "t_wall_s": round(time.perf_counter() - t0, 4),
                     **traffic_fields(rt), **danger_fields(rt)})
    for p in (16, 64, 256):
        n = (1 << 17) * p   # 8-page sliding windows over 128-page blocks
        cache_pages = 20    # ~1.2 of the 16-page read+write window pair
        ss = SteadyState()
        t0 = time.perf_counter()
        rt = make_rt("samhita", p, cache_pages=cache_pages)
        stream_refetch(rt, n, max(2, iters // 2), sweeps=2, width_pages=8,
                       driver=driver, on_iter=ss)
        rows.append({"figure": "fig4_refetch", "series": "samhita_refetch",
                     "p": p, "n": n, "driver": driver,
                     "t_iter_s": round(ss.per_iter(), 6),
                     "net_bytes": rt.traffic.total_bytes,
                     "t_model_s": round(rt.time, 6),
                     "t_wall_s": round(time.perf_counter() - t0, 4),
                     **traffic_fields(rt), **danger_fields(rt)})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--weak", action="store_true")
    ap.add_argument("--spill", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--driver", choices=["loop", "batched"],
                    default="batched",
                    help="SPMD phase driver: per-worker loop or phase_all")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write machine-readable rows here")
    args = ap.parse_args(argv)
    rows = []
    if args.all or not (args.weak or args.spill):
        rows += strong(args.iters, args.driver)
    if args.all or args.weak:
        rows += weak(args.iters, args.driver)
    if args.all or args.spill:
        rows += spill(spill_iters(args.iters), args.driver)
    # non-default drivers get their own CSV so `--driver both` harness
    # runs don't overwrite the batched rows
    write_csv("stream_triad" if args.driver == "batched"
              else f"stream_triad_{args.driver}", rows)
    if args.json:
        write_bench_json(args.json, rows)
    print_rows(rows)
    return rows


if __name__ == "__main__":
    main()
