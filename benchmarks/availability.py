"""Availability under process faults — the cluster bench
(fig10_availability).

Runs the recovery bench's deterministic phase program on the
partition-tolerant multi-process runtime (``repro.cluster``): the worker
axis sharded across 1/2/4 spawned OS processes behind the control plane,
at W = 16/64/256, each point twice — clean, and with an injected
mid-phase SIGKILL plus a one-directional link partition (the ``_fault``
series) recovered in degraded mode.  Measured: end-to-end wall
throughput (events/s) and p50/p99 barrier-round latency from the control
plane's real-wall barrier clock, plus checkpoint/replay volume.

Every row carries the exact ``tr_*`` traffic fields, the ``chaos_*`` /
``straggler_*`` counters, AND the deterministic ``rec_*`` recovery
counters (detections, kills, partitions, respawns, replayed events,
composed checkpoints, digest agreement rounds) — all gated
field-for-field by ``benchmarks.compare``: the committed results PROVE
the failure paths fired and were recovered, and the bench itself asserts
every sharded run (clean AND faulted) finishes traffic field-for-field
and clock bit-equal to the unfailed single-process run — the paper's
exactness bar held through process death.  Modeled time is identical
across shard counts and fault variants by construction: real-wall RPC
retries are accounted in ``rpc_retry_model_s`` (via
``ChaosNet.backoff_seconds``), never charged to the modeled clocks.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import tempfile
import time

import numpy as np

from benchmarks.common import (SERIES, chaos_fields, print_rows,
                               write_bench_json, write_csv)
from benchmarks.recovery import CHAOS_SEED, DROP_RATE, apply_event  # noqa: F401  (apply_event: shard apply_ref target)
from benchmarks.recovery import gen_program
from repro.cluster import ClusterRuntime
from repro.dsm.costmodel import IB_2013
from repro.ft import FailureInjector
from repro.ft.coherence import assert_bit_equal, run_uninjected

PAGE_WORDS = 1024
PAGES_PER_WORKER = 8
CORES = (16, 64, 256)
SHARDS = (1, 2, 4)
RPC_TIMEOUT_S = 0.25
RPC_ATTEMPTS = 3


def _cfg(W: int) -> dict:
    return dict(n_workers=W, page_words=PAGE_WORDS,
                protocol=SERIES["samhita"], cache_pages=None,
                fetch_batch=16, cost=dataclasses.asdict(IB_2013),
                # same pure-observer knob as common.make_rt: flipping it
                # must not change a single committed cluster number
                detect_races=os.environ.get("BENCH_DETECT_RACES") == "1",
                chaos=dict(seed=CHAOS_SEED, drop_rate=DROP_RATE),
                straggler=dict(n_workers=W, window=4, k=4.0,
                               abs_floor_s=1e-4, patience=2))


def _fault_schedule(iters: int, n_shards: int):
    """Two deterministic process faults per faulted run: SIGKILL the
    last rank mid-iteration (between the phase and span events, NOT at
    a barrier — so the replay suffix is provably non-empty), then a
    one-directional reply partition on rank 0 a few events later (on
    the respawned process itself when n_shards == 1)."""
    n_events = 3 * iters
    kill_step = 3 * max(1, iters // 2) + 2          # the span event
    part_step = min(n_events, kill_step + 3)
    return [("kill", kill_step, n_shards - 1),
            ("partition_s2c", part_step, 0)]


def availability(iters: int, driver: str, cores=CORES, shards=SHARDS):
    from repro.cluster.shard import make_runtime

    rows = []
    for p in cores:
        n_words = PAGE_WORDS * PAGES_PER_WORKER * p
        cfg = _cfg(p)
        prog = gen_program(p, n_words, iters)
        base = run_uninjected(lambda: make_runtime(cfg), [n_words],
                              driver, prog, apply_event)
        for n_shards in shards:
            for fault in (False, True):
                inj = (FailureInjector(
                    cluster_at=_fault_schedule(iters, n_shards))
                    if fault else None)
                with tempfile.TemporaryDirectory() as td:
                    t0 = time.perf_counter()
                    with ClusterRuntime(
                            cfg, [n_words], n_shards=n_shards,
                            driver=driver,
                            apply_ref=("benchmarks.recovery",
                                       "apply_event"),
                            root=td, injector=inj,
                            rpc_timeout_s=RPC_TIMEOUT_S,
                            rpc_attempts=RPC_ATTEMPTS) as cluster:
                        res = cluster.run(prog)
                    t_wall = time.perf_counter() - t0
                rep = res.report
                series = f"samhita_s{n_shards}" + ("_fault" if fault
                                                   else "")
                # the exactness bar as a bench invariant: every sharded
                # run — through SIGKILL and partition — finishes
                # bit-equal to the unfailed single-process run
                assert_bit_equal(res, base, (series, p, driver))
                if fault:
                    assert rep.kills == 1 and rep.partitions == 1, rep
                    assert rep.detections == 2, rep
                else:
                    assert rep.detections == 0, rep
                bar_ms = np.asarray(rep.bar_wall_s) * 1e3
                rows.append({
                    "figure": "fig10_availability", "series": series,
                    "p": p, "n": n_words, "driver": driver,
                    "n_shards": n_shards,
                    "t_model_s": round(res.time, 6),
                    "t_wall_s": round(t_wall, 4),
                    "events_per_s": round(rep.n_events / t_wall, 2),
                    "bar_p50_ms": round(float(np.percentile(bar_ms, 50)),
                                        3),
                    "bar_p99_ms": round(float(np.percentile(bar_ms, 99)),
                                        3),
                    "n_events": rep.n_events,
                    "rpc_retries": rep.rpc_retries,
                    "rpc_retry_model_s": round(rep.rpc_retry_model_s, 6),
                    **rep.counters(),
                    "net_bytes": res.traffic.total_bytes,
                    **{f"tr_{f.name}": getattr(res.traffic, f.name)
                       for f in dataclasses.fields(type(res.traffic))},
                    **chaos_fields(res)})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=6,
                    help="barrier-delimited iterations per point")
    ap.add_argument("--driver", choices=["loop", "batched"],
                    default="batched")
    ap.add_argument("--smoke", action="store_true",
                    help="quick local subset (W <= 64, shards <= 2).  "
                         "Missing committed keys routes the output to "
                         "*.partial.csv, so the committed artifacts stay "
                         "untouched")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write machine-readable rows here")
    args = ap.parse_args(argv)
    rows = availability(args.iters, args.driver,
                        cores=CORES[:2] if args.smoke else CORES,
                        shards=SHARDS[:2] if args.smoke else SHARDS)
    write_csv("availability" if args.driver == "batched"
              else f"availability_{args.driver}", rows)
    if args.json:
        write_bench_json(args.json, rows)
    print_rows(rows)
    return rows


if __name__ == "__main__":
    main()
