"""Shared benchmark plumbing: runtime factory (paper series names), steady-
state timing, CSV + machine-readable JSON emission."""
from __future__ import annotations

import csv
import json
import os
import warnings
from pathlib import Path
from typing import Dict, List, Optional

from repro.core import FINE_PROTO, IDEAL_PROTO, PAGE_PROTO, make_runtime
from repro.core.regc_scale import RegCScaleRuntime
from repro.dsm.costmodel import IB_2013

# paper series -> protocol
SERIES = {
    "pthreads": IDEAL_PROTO,
    "samhita": FINE_PROTO,        # fine-grain consistency-region updates
    "samhita_page": PAGE_PROTO,   # page invalidation everywhere
}

OUT_DIR = Path(os.environ.get("BENCH_OUT", "artifacts/bench"))


def make_rt(series: str, workers: int, **kw) -> RegCScaleRuntime:
    kw.setdefault("cost", IB_2013)
    kw.setdefault("fetch_batch", 16)   # Samhita's bulk-fetch optimization
    # BENCH_DETECT_RACES=1 flips race detection on for EVERY bench point:
    # the pure-observer check — no committed traffic or modeled-time
    # number may change (benchmarks.compare --strict-model verifies)
    kw.setdefault("detect_races",
                  os.environ.get("BENCH_DETECT_RACES") == "1")
    return make_runtime(workers, protocol=SERIES[series], **kw)


def traffic_fields(rt) -> Dict[str, int]:
    """Exact per-point protocol traffic, flattened for CSV/JSON rows
    (``tr_`` prefix).  ``benchmarks.compare`` diffs these field-for-field
    and fails on ANY mismatch — the exactness regression gate."""
    import dataclasses
    return {f"tr_{f.name}": getattr(rt.traffic, f.name)
            for f in dataclasses.fields(type(rt.traffic))}


def danger_fields(rt) -> Dict[str, int]:
    """Danger-path counters for the spill sections: how many
    danger-flagged ops the vectorized refetch schedule absorbed vs how
    many fell back to the scalar page walk.  Recorded per row so the
    committed results PROVE the vectorized path (not the fallback) ran
    the spill regimes."""
    stats = getattr(rt, "stats", {})
    return {"danger_vec": stats.get("danger_vec_ops", 0),
            "danger_scalar": stats.get("danger_scalar_ops", 0),
            "danger_shared": stats.get("danger_shared_ops", 0)}


def chaos_fields(rt) -> Dict[str, int]:
    """Chaos/straggler counters for the recovery section: message-loss
    ticks, drops, invalidation retransmissions, and barrier straggler
    checks/flags.  Recorded per row (and gated by ``benchmarks.compare``
    like the traffic fields) so the committed results PROVE the
    injection and retry paths fired — no silently-idle chaos."""
    stats = getattr(rt, "stats", {})
    return {"chaos_msgs": stats.get("chaos_msgs", 0),
            "chaos_drops": stats.get("chaos_drops", 0),
            "chaos_inval_retries": stats.get("chaos_inval_retries", 0),
            "straggler_checks": stats.get("straggler_checks", 0),
            "straggler_flags": stats.get("straggler_flags", 0)}


def race_fields(rt) -> Dict[str, int]:
    """Race-detector counters for the fig11 section: distinct flagged
    write/write and read/write page races.  Deterministic (detection is
    exact at page granularity over declared ranges), so gated by
    ``benchmarks.compare`` like the ``danger_*``/``span_*`` counters —
    the committed results PROVE the detector flagged the seeded races,
    not silently idled."""
    stats = getattr(rt, "stats", {})
    return {"race_ww": stats.get("race_ww", 0),
            "race_rw": stats.get("race_rw", 0)}


def span_fields(rt) -> Dict[str, int]:
    """Span-engine path counters for the lock sections: how many span
    bodies the analytic batched group pass absorbed vs how many fell
    back to the per-worker serial body.  Recorded per row (and gated by
    ``benchmarks.compare`` like the danger counters) so the committed
    results PROVE the pipelined path ran the contended regimes."""
    stats = getattr(rt, "stats", {})
    return {"span_vec": stats.get("span_workers_vec", 0),
            "span_serial": stats.get("span_serial_workers", 0)}


def jit_fields(rt_or_stats) -> Dict[str, int]:
    """Fused-dispatch counters for the 'pallas-jit' tier.
    ``jit_dispatches`` (how many fused device programs actually ran) is
    deterministic per point and gated by ``benchmarks.compare`` like the
    traffic fields — a zero on a jit-backed point is the silent
    numpy-fallback signature.  ``jit_cache_misses`` mirrors jax's
    process-wide compile cache (it depends on what ran earlier in the
    process), so it is emitted as un-prefixed ``compiles`` — report-only,
    outside the gate."""
    stats = getattr(rt_or_stats, "stats", rt_or_stats) or {}
    return {"jit_dispatches": stats.get("jit_dispatches", 0),
            "compiles": stats.get("jit_cache_misses", 0)}


class SteadyState:
    """Capture per-iteration modeled time, skipping the cold first iter."""

    def __init__(self):
        self.times: List[float] = []

    def __call__(self, it, rt):
        self.times.append(rt.time)

    def per_iter(self) -> float:
        if not self.times:
            raise ValueError("per_iter(): no iterations recorded")
        if len(self.times) < 3:
            warnings.warn(
                f"per_iter(): only {len(self.times)} iteration(s) recorded; "
                "steady-state estimate degrades to mean of available "
                "(run with --iters >= 3 for a cold-start-free figure)",
                RuntimeWarning, stacklevel=2)
            if len(self.times) == 1:
                return self.times[0]
        return (self.times[-1] - self.times[0]) / (len(self.times) - 1)


def _point_keys(rows) -> set:
    return {(r.get("figure"), r.get("series"), str(r.get("p")))
            for r in rows}


def write_csv(name: str, rows: List[Dict]):
    """Write section rows to ``artifacts/bench/<name>.csv``.

    The committed CSVs are ground truth for the no-drift tests and the
    compare traffic gate, so a *partial* invocation (e.g. a single-figure
    or smoke run) must not clobber a richer artifact: if the existing
    file covers (figure, series, p) points the new rows lack, the rows
    land in ``<name>.partial.csv`` instead, with a printed notice.
    ``BENCH_REFRESH=1`` overrides the guard — the escape hatch for
    deliberate point removals/renames, which would otherwise leave a
    stale key in the committed file forever."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"{name}.csv"
    if path.exists() and os.environ.get("BENCH_REFRESH") != "1":
        try:
            with open(path, newline="") as fh:
                old_keys = _point_keys(csv.DictReader(fh))
        except Exception:
            old_keys = set()
        missing = old_keys - _point_keys(rows)
        if missing:
            partial = OUT_DIR / f"{name}.partial.csv"
            print(f"write_csv: {path} covers {len(missing)} point(s) this "
                  f"run lacks; writing {partial} instead (BENCH_REFRESH=1 "
                  "forces a refresh after deliberate point removals)")
            path = partial
    fields: List[str] = []
    for r in rows:                     # union of keys, first-seen order
        for k in r:
            if k not in fields:
                fields.append(k)
    with open(path, "w", newline="") as fh:
        w = csv.DictWriter(fh, fieldnames=fields, restval="")
        w.writeheader()
        w.writerows(rows)
    return path


def print_rows(rows: List[Dict]):
    for r in rows:
        print(",".join(str(v) for v in r.values()), flush=True)
    print()


# ---------------------------------------------------------------------------
# machine-readable results (BENCH_scale.json) for perf-trajectory tracking
# ---------------------------------------------------------------------------


def bench_json_rows(rows: List[Dict]) -> List[Dict]:
    """Normalize section rows to the BENCH_scale.json schema:
    {section, protocol, W, t_wall_s, t_model_s, total_bytes}.  Handles the
    three row shapes the harness produces: protocol sections (figure/
    series/p), regc_training (policy), and roofline (arch/shape/mesh)."""
    out = []
    for r in rows:
        if "series" in r:              # protocol sections
            out.append({
                "section": r["figure"], "protocol": r["series"],
                "W": r["p"], "driver": r.get("driver", "loop"),
                "t_wall_s": r.get("t_wall_s"),
                "t_model_s": r.get("t_model_s", r.get("t_iter_s")),
                "total_bytes": r.get("net_bytes", 0),
                **{k: v for k, v in r.items()
                   if k.startswith("tr_") or k.startswith("danger_")
                   or k.startswith("span_") or k.startswith("chaos_")
                   or k.startswith("straggler_")
                   or k.startswith("rec_") or k.startswith("race_")
                   or k.startswith("srv_") or k.startswith("jit_")}})
        elif "policy" in r:            # regc_training (8-way DP mesh)
            out.append({
                "section": "regc_training", "protocol": r["policy"],
                "W": 8, "t_wall_s": r.get("wall_s_per_step"),
                "t_model_s": None,
                "total_bytes": r.get("collective_bytes_per_dev", 0)})
        elif "mesh" in r:              # roofline (modeled per-cell times)
            devs = 1
            for d in str(r["mesh"]).split("x"):
                devs *= int(d)
            t_model = (r.get("t_compute_ms", 0) + r.get("t_memory_ms", 0)
                       + r.get("t_collective_ms", 0)) / 1e3
            out.append({
                "section": f"roofline_{r.get('variant', '?')}",
                "protocol": f"{r.get('arch', '?')}:{r.get('shape', '?')}",
                "W": devs, "t_wall_s": None,
                "t_model_s": round(t_model, 6), "total_bytes": 0})
        else:
            out.append({"section": "?", "protocol": "?", "W": 0,
                        "t_wall_s": None, "t_model_s": None,
                        "total_bytes": 0, "raw": r})
    return out


def write_bench_json(path, rows: List[Dict],
                     meta: Optional[Dict] = None) -> Path:
    p = Path(path)
    if str(p.parent) not in ("", "."):
        p.parent.mkdir(parents=True, exist_ok=True)
    payload = {"meta": meta or {}, "rows": bench_json_rows(rows)}
    p.write_text(json.dumps(payload, indent=1) + "\n")
    return p
