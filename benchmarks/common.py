"""Shared benchmark plumbing: runtime factory (paper series names), steady-
state timing, CSV emission."""
from __future__ import annotations

import csv
import os
from pathlib import Path
from typing import Dict, List

from repro.core import FINE_PROTO, IDEAL_PROTO, PAGE_PROTO
from repro.core.regc_scale import RegCScaleRuntime
from repro.dsm.costmodel import IB_2013

# paper series -> protocol
SERIES = {
    "pthreads": IDEAL_PROTO,
    "samhita": FINE_PROTO,        # fine-grain consistency-region updates
    "samhita_page": PAGE_PROTO,   # page invalidation everywhere
}

OUT_DIR = Path(os.environ.get("BENCH_OUT", "artifacts/bench"))


def make_rt(series: str, workers: int, **kw) -> RegCScaleRuntime:
    kw.setdefault("cost", IB_2013)
    kw.setdefault("fetch_batch", 16)   # Samhita's bulk-fetch optimization
    return RegCScaleRuntime(workers, protocol=SERIES[series], **kw)


class SteadyState:
    """Capture per-iteration modeled time, skipping the cold first iter."""

    def __init__(self):
        self.times: List[float] = []

    def __call__(self, it, rt):
        self.times.append(rt.time)

    def per_iter(self) -> float:
        assert len(self.times) >= 3, "need >= 3 iterations"
        return (self.times[-1] - self.times[0]) / (len(self.times) - 1)


def write_csv(name: str, rows: List[Dict]):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"{name}.csv"
    fields: List[str] = []
    for r in rows:                     # union of keys, first-seen order
        for k in r:
            if k not in fields:
                fields.append(k)
    with open(path, "w", newline="") as fh:
        w = csv.DictWriter(fh, fieldnames=fields, restval="")
        w.writeheader()
        w.writerows(rows)
    return path


def print_rows(rows: List[Dict]):
    for r in rows:
        print(",".join(str(v) for v in r.values()), flush=True)
    print()
