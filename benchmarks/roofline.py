"""Roofline summary benchmark: condense the dry-run artifacts into the
per-cell three-term table (compute / memory / collective seconds, dominant
term, MFU upper bound).  The full dry-run sweep is launched via
``python -m repro.launch.dryrun --all`` (512 placeholder devices); this
reader never initializes extra devices — in a fresh checkout it
auto-generates a small seed set of cells in a subprocess on first run
(``--no-auto`` disables)."""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

from benchmarks.common import print_rows, write_csv

ART = Path("artifacts/dryrun")

# small cells lowered on first run when no artifacts exist yet (an attn
# and an SSM arch; ~10-20s each — the 512-device sweep stays manual)
SEED_CELLS = (("internlm2-1.8b", "train_4k"), ("mamba2-2.7b", "train_4k"))


def ensure_artifacts(variant: str = "baseline") -> bool:
    """Generate the seed dry-run cells if none exist for ``variant``.
    Runs dryrun in a subprocess: it forces a 512-device jax at import,
    which must not leak into this process.  Returns True when artifacts
    are available afterwards."""
    if any(ART.glob(f"*__{variant}.json")):
        return True
    if variant != "baseline":
        return False               # only the baseline seed set is automatic
    print(f"no dry-run artifacts under {ART}; generating seed cells "
          f"{SEED_CELLS} (use `python -m repro.launch.dryrun --all` for "
          "the full sweep)", flush=True)
    for arch, shape in SEED_CELLS:
        try:
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                 "--shape", shape, "--mesh", "single", "--out", str(ART)],
                capture_output=True, text=True, timeout=560)
        except (subprocess.TimeoutExpired, OSError) as e:
            print(f"dry-run seed cell {arch}/{shape} failed: {e}", flush=True)
            continue
        if r.returncode != 0:
            print(f"dry-run seed cell {arch}/{shape} failed:\n"
                  f"{r.stdout[-1000:]}\n{r.stderr[-1000:]}", flush=True)
    return any(ART.glob(f"*__{variant}.json"))


def load_rows(variant: str = "baseline", mesh: str = None):
    rows = []
    for f in sorted(ART.glob(f"*__{variant}.json")):
        r = json.loads(f.read_text())
        if not r.get("ok"):
            continue
        if mesh and r["mesh"] != mesh:
            continue
        roof = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "variant": r["variant"],
            "t_compute_ms": round(roof["t_compute_s"] * 1e3, 3),
            "t_memory_ms": round(roof["t_memory_s"] * 1e3, 3),
            "t_collective_ms": round(roof["t_collective_s"] * 1e3, 3),
            "dominant": roof["dominant"],
            "model/hlo_flops": round(roof["model_flops/hlo_flops"], 3),
            "mfu_upper_bound": round(roof["mfu_upper_bound"], 4),
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--no-auto", action="store_true",
                    help="do not auto-generate seed dry-run artifacts")
    args = ap.parse_args(argv)
    if not args.no_auto:
        ensure_artifacts(args.variant)
    rows = load_rows(args.variant, args.mesh)
    if not rows:
        print(f"no dry-run artifacts for variant={args.variant} "
              f"(run: python -m repro.launch.dryrun --all --mesh both)")
        return []
    write_csv(f"roofline_{args.variant}", rows)
    print_rows(rows)
    return rows


if __name__ == "__main__":
    main()
