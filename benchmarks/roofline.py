"""Roofline summary benchmark: condense the dry-run artifacts into the
per-cell three-term table (compute / memory / collective seconds, dominant
term, MFU upper bound).  The dry-run sweep itself is launched via
``python -m repro.launch.dryrun --all`` (512 placeholder devices); this
reader never initializes extra devices."""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import print_rows, write_csv

ART = Path("artifacts/dryrun")


def load_rows(variant: str = "baseline", mesh: str = None):
    rows = []
    for f in sorted(ART.glob(f"*__{variant}.json")):
        r = json.loads(f.read_text())
        if not r.get("ok"):
            continue
        if mesh and r["mesh"] != mesh:
            continue
        roof = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "variant": r["variant"],
            "t_compute_ms": round(roof["t_compute_s"] * 1e3, 3),
            "t_memory_ms": round(roof["t_memory_s"] * 1e3, 3),
            "t_collective_ms": round(roof["t_collective_s"] * 1e3, 3),
            "dominant": roof["dominant"],
            "model/hlo_flops": round(roof["model_flops/hlo_flops"], 3),
            "mfu_upper_bound": round(roof["mfu_upper_bound"], 4),
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args(argv)
    rows = load_rows(args.variant, args.mesh)
    if not rows:
        print(f"no dry-run artifacts for variant={args.variant} "
              f"(run: python -m repro.launch.dryrun --all --mesh both)")
        return []
    write_csv(f"roofline_{args.variant}", rows)
    print_rows(rows)
    return rows


if __name__ == "__main__":
    main()
