"""Diff two ``BENCH_scale.json`` files and fail on wall-time regressions.

``python -m benchmarks.compare BASE NEW [--threshold 0.3] [--min-wall 0.2]``
exits non-zero when a per-section wall time (or the total) regressed by more
than ``threshold`` (relative), ignoring sections faster than ``min-wall``
seconds (pure noise on a busy box).  Point rows are matched on
(section, protocol, W, driver) and compared on modeled time and traffic —
those are deterministic, so ANY drift is reported (report-only by default;
``--strict-model`` turns modeled/traffic drift into failures too).

``benchmarks.run --fast`` smoke-invokes :func:`report` against the previous
JSON so every fast run prints its own trajectory.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Tuple


def _section_walls(data: Dict) -> Dict[str, float]:
    out = {}
    for name, m in (data.get("meta", {}).get("sections", {}) or {}).items():
        if m.get("status") == "ok" and m.get("wall_s") is not None:
            out[name] = float(m["wall_s"])
    return out


def _point_key(r: Dict) -> Tuple:
    return (r.get("section"), r.get("protocol"), r.get("W"),
            r.get("driver", "loop"))


def diff(base: Dict, new: Dict, *, threshold: float = 0.3,
         min_wall: float = 0.2) -> Tuple[List[str], List[str], int]:
    """Returns (regressions, notes, n_model_drift): regressions are gate
    failures, notes are informational lines, n_model_drift counts points
    whose deterministic modeled time / traffic changed."""
    regressions, notes = [], []

    bw, nw = _section_walls(base), _section_walls(new)
    for name in sorted(bw.keys() & nw.keys()):
        b, n = bw[name], nw[name]
        if max(b, n) < min_wall:
            continue
        rel = (n - b) / b if b else float("inf")
        line = f"section {name}: wall {b:.2f}s -> {n:.2f}s ({rel:+.0%})"
        if rel > threshold:
            regressions.append(line)
        else:
            notes.append(line)
    bt = base.get("meta", {}).get("total_wall_s")
    nt = new.get("meta", {}).get("total_wall_s")
    if bt and nt:
        rel = (nt - bt) / bt
        line = f"total: wall {bt:.2f}s -> {nt:.2f}s ({rel:+.0%})"
        (regressions if rel > threshold else notes).append(line)

    b_rows = {_point_key(r): r for r in base.get("rows", [])}
    n_rows = {_point_key(r): r for r in new.get("rows", [])}
    drift = 0
    for k in sorted(b_rows.keys() & n_rows.keys(), key=str):
        br, nr = b_rows[k], n_rows[k]
        if br.get("total_bytes") != nr.get("total_bytes"):
            drift += 1
            notes.append(f"point {k}: traffic {br.get('total_bytes')} -> "
                         f"{nr.get('total_bytes')}")
        elif (br.get("t_model_s") is not None
              and br.get("t_model_s") != nr.get("t_model_s")):
            drift += 1
            notes.append(f"point {k}: t_model {br.get('t_model_s')} -> "
                         f"{nr.get('t_model_s')}")
    only_b = b_rows.keys() - n_rows.keys()
    only_n = n_rows.keys() - b_rows.keys()
    if only_b:
        notes.append(f"{len(only_b)} point(s) only in base")
    if only_n:
        notes.append(f"{len(only_n)} point(s) only in new")
    if drift:
        notes.append(f"{drift} point(s) drifted in modeled time/traffic")
    return regressions, notes, drift


def report(base: Dict, new: Dict, *, threshold: float = 0.3,
           min_wall: float = 0.2, strict_model: bool = False) -> int:
    regressions, notes, drift = diff(base, new, threshold=threshold,
                                     min_wall=min_wall)
    for line in notes:
        print(f"  {line}")
    for line in regressions:
        print(f"  REGRESSION: {line}")
    if not regressions and not notes:
        print("  no comparable entries")
    failed = bool(regressions) or (strict_model and drift > 0)
    print(f"  verdict: {'FAIL' if failed else 'ok'} "
          f"({len(regressions)} wall regression(s))")
    return 1 if failed else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("base", help="baseline BENCH_scale.json")
    ap.add_argument("new", help="candidate BENCH_scale.json")
    ap.add_argument("--threshold", type=float, default=0.3,
                    help="relative wall-time regression gate "
                         "(default: %(default)s)")
    ap.add_argument("--min-wall", type=float, default=0.2,
                    help="ignore sections faster than this many seconds")
    ap.add_argument("--strict-model", action="store_true",
                    help="also fail on modeled-time/traffic drift")
    args = ap.parse_args(argv)
    base = json.loads(Path(args.base).read_text())
    new = json.loads(Path(args.new).read_text())
    return report(base, new, threshold=args.threshold,
                  min_wall=args.min_wall, strict_model=args.strict_model)


if __name__ == "__main__":
    sys.exit(main())
