"""Diff two ``BENCH_scale.json`` files; fail on wall-time regressions AND
on any exact-traffic drift.

``python -m benchmarks.compare BASE NEW [--threshold 0.3] [--min-wall 0.2]
[--sections SUBSTR ...]`` exits non-zero when

* a per-section wall time (or the total) regressed by more than
  ``threshold`` (relative), ignoring sections faster than ``min-wall``
  seconds (pure noise on a busy box); or
* a point's exact protocol traffic changed — ``total_bytes`` or any
  ``tr_*`` field both files carry — or its deterministic ``danger_*`` /
  ``span_*`` / ``chaos_*`` / ``straggler_*`` / ``rec_*`` / ``race_*`` /
  ``srv_*`` counters did
  (a spill or lock regime silently flipping
  from the vectorized schedule to a scalar fallback keeps traffic
  identical but is a perf regression).  Traffic is deterministic (the
  runtime's exactness invariant), so a mismatch is a correctness
  regression, not noise, and always fails — spill sections included.

Point rows match on (section, protocol, W, driver).  Modeled-time drift
stays report-only unless ``--strict-model``.  ``--sections`` restricts
the diff to sections/protocols containing any given substring (e.g.
``--sections spill``).

``benchmarks.run --fast`` smoke-invokes :func:`report` against the
previous JSON — once in full and once focused on the spill sections — so
every fast run prints its own trajectory and traffic gate.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple


def _section_walls(data: Dict) -> Dict[str, float]:
    out = {}
    for name, m in (data.get("meta", {}).get("sections", {}) or {}).items():
        if m.get("status") == "ok" and m.get("wall_s") is not None:
            out[name] = float(m["wall_s"])
    return out


def _point_key(r: Dict) -> Tuple:
    return (r.get("section"), r.get("protocol"), r.get("W"),
            r.get("driver", "loop"))


def _keep(name, sections: Optional[List[str]]) -> bool:
    return sections is None or any(s in str(name) for s in sections)


def diff(base: Dict, new: Dict, *, threshold: float = 0.3,
         min_wall: float = 0.2,
         sections: Optional[List[str]] = None
         ) -> Tuple[List[str], List[str], int]:
    """Returns (regressions, notes, n_model_drift): regressions are gate
    failures (wall regressions AND exact-traffic mismatches), notes are
    informational lines, n_model_drift counts points whose deterministic
    modeled time changed.  ``sections`` filters by substring."""
    regressions, notes = [], []

    bw, nw = _section_walls(base), _section_walls(new)
    for name in sorted(bw.keys() & nw.keys()):
        if not _keep(name, sections):
            continue
        b, n = bw[name], nw[name]
        if max(b, n) < min_wall:
            continue
        rel = (n - b) / b if b else float("inf")
        line = f"section {name}: wall {b:.2f}s -> {n:.2f}s ({rel:+.0%})"
        if rel > threshold:
            regressions.append(line)
        else:
            notes.append(line)
    bt = base.get("meta", {}).get("total_wall_s")
    nt = new.get("meta", {}).get("total_wall_s")
    if bt and nt and sections is None:
        rel = (nt - bt) / bt
        line = f"total: wall {bt:.2f}s -> {nt:.2f}s ({rel:+.0%})"
        (regressions if rel > threshold else notes).append(line)

    b_rows = {_point_key(r): r for r in base.get("rows", [])}
    n_rows = {_point_key(r): r for r in new.get("rows", [])}
    drift = 0
    n_compared = 0
    for k in sorted(b_rows.keys() & n_rows.keys(), key=str):
        if not (_keep(k[0], sections) or _keep(k[1], sections)):
            continue
        n_compared += 1
        br, nr = b_rows[k], n_rows[k]
        # exact traffic: total_bytes plus every tr_* field both runs
        # recorded, and the danger-path counters (which engine resolved
        # the spill regimes — a silent flip to the scalar fallback keeps
        # traffic identical but IS a regression).  Deterministic -> any
        # mismatch is a gate failure.
        tfields = ["total_bytes"] + sorted(
            set(f for f in br
                if f.startswith("tr_") or f.startswith("danger_")
                or f.startswith("span_") or f.startswith("chaos_")
                or f.startswith("straggler_") or f.startswith("rec_")
                or f.startswith("race_") or f.startswith("srv_")
                or f.startswith("jit_"))
            & set(nr))
        bad = [f for f in tfields if br.get(f) != nr.get(f)]
        if bad:
            regressions.append(
                "point %s: TRAFFIC mismatch %s" % (k, ", ".join(
                    f"{f} {br.get(f)} -> {nr.get(f)}" for f in bad)))
        elif (br.get("t_model_s") is not None
              and br.get("t_model_s") != nr.get("t_model_s")):
            drift += 1
            notes.append(f"point {k}: t_model {br.get('t_model_s')} -> "
                         f"{nr.get('t_model_s')}")
    sd_new = {(k[0], k[3]) for k in n_rows}
    only_b = [k for k in b_rows.keys() - n_rows.keys()
              if _keep(k[0], sections) or _keep(k[1], sections)]
    # a vanished point IS a traffic regression (its exact counters are
    # gone) — but only when the new run actually exercised that
    # (section, driver) pairing; a --driver batched run diffed against a
    # --driver both baseline, or a partial-section run, just didn't run
    # the others.  New points are additions and stay informational.
    gone = [k for k in only_b if (k[0], k[3]) in sd_new]
    skipped = len(only_b) - len(gone)
    if gone:
        ex = ", ".join(str(k) for k in sorted(gone, key=str)[:3])
        regressions.append(
            f"{len(gone)} point(s) VANISHED vs base (e.g. {ex})")
    if skipped:
        notes.append(f"{skipped} base point(s) whose (section, driver) "
                     "was not run")
    only_n = [k for k in n_rows.keys() - b_rows.keys()
              if _keep(k[0], sections) or _keep(k[1], sections)]
    if only_n:
        notes.append(f"{len(only_n)} point(s) only in new")
    if n_compared:
        notes.append(f"{n_compared} point(s) compared "
                     f"({drift} modeled-time drift(s), traffic exact "
                     "on the rest)" if drift else
                     f"{n_compared} point(s) compared, traffic and "
                     "modeled time exact")
    return regressions, notes, drift


def report(base: Dict, new: Dict, *, threshold: float = 0.3,
           min_wall: float = 0.2, strict_model: bool = False,
           sections: Optional[List[str]] = None) -> int:
    regressions, notes, drift = diff(base, new, threshold=threshold,
                                     min_wall=min_wall, sections=sections)
    for line in notes:
        print(f"  {line}")
    for line in regressions:
        print(f"  REGRESSION: {line}")
    if not regressions and not notes:
        print("  no comparable entries")
    failed = bool(regressions) or (strict_model and drift > 0)
    print(f"  verdict: {'FAIL' if failed else 'ok'} "
          f"({len(regressions)} regression(s))")
    return 1 if failed else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("base", help="baseline BENCH_scale.json")
    ap.add_argument("new", help="candidate BENCH_scale.json")
    ap.add_argument("--threshold", type=float, default=0.3,
                    help="relative wall-time regression gate "
                         "(default: %(default)s)")
    ap.add_argument("--min-wall", type=float, default=0.2,
                    help="ignore sections faster than this many seconds")
    ap.add_argument("--strict-model", action="store_true",
                    help="also fail on modeled-time drift")
    ap.add_argument("--sections", nargs="+", default=None, metavar="SUBSTR",
                    help="restrict the diff to sections/protocols "
                         "containing any of these substrings "
                         "(e.g. --sections spill)")
    args = ap.parse_args(argv)
    base = json.loads(Path(args.base).read_text())
    new = json.loads(Path(args.new).read_text())
    return report(base, new, threshold=args.threshold,
                  min_wall=args.min_wall, strict_model=args.strict_model,
                  sections=args.sections)


if __name__ == "__main__":
    sys.exit(main())
