"""Jacobi — paper Figs. 5 (strong scaling + the reduction extension) and 6
(weak scaling computation rate).

Four Samhita series (the paper's): {samhita, samhita_page} x {lock,
reduction} + the Pthreads baseline.  Speedup is relative to 1-core Pthreads
(paper Fig. 5); weak scaling reports computation rate (stencil points/s).
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import (SteadyState, danger_fields, make_rt,
                               print_rows, traffic_fields, write_bench_json,
                               write_csv)
from repro.dsm.apps import jacobi, jacobi_flops_per_iter

N_BASE = 4096
CORES = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def _run(series: str, mode: str, p: int, n: int, iters: int,
         driver: str = "batched", **rt_kw):
    ss = SteadyState()
    t0 = time.perf_counter()
    rt = make_rt(series, p, **rt_kw)
    jacobi(rt, n, iters, mode=mode, driver=driver, on_iter=ss)
    return ss.per_iter(), rt, time.perf_counter() - t0


def spill(iters: int, driver: str):
    """Jacobi under capacity pressure: the cache holds ~half the
    per-worker 3-grid working set, so phase 2's halo reads evict phase
    1's copies every iteration.  Halo overlap + prefetch put every
    spilling worker inside its neighbours' reach, so the batched driver's
    disjointness analysis replays them tick-ordered — traffic must stay
    bit-identical to the loop driver (asserted in tests; recorded here)."""
    rows = []
    n = N_BASE
    for p in (16, 64, 256):
        cache_pages = max((3 * (n * n // 1024)) // (2 * p), 8)
        t, rt, t_wall = _run("samhita", "reduction", p, n, iters, driver,
                             cache_pages=cache_pages)
        rows.append({"figure": "fig5_spill", "series": "samhita_spill",
                     "p": p, "n": n, "driver": driver,
                     "t_iter_s": round(t, 6),
                     "net_bytes": rt.traffic.total_bytes,
                     "t_model_s": round(rt.time, 6),
                     "t_wall_s": round(t_wall, 4),
                     **traffic_fields(rt), **danger_fields(rt)})
    return rows


def strong(iters: int, driver: str):
    rows = []
    t_ref, _, _ = _run("pthreads", "reduction", 1, N_BASE, iters, driver)
    variants = [("pthreads", "reduction", "pthreads")] + [
        (s, m, f"{s}_{m}")
        for s in ("samhita", "samhita_page") for m in ("lock", "reduction")]
    for p in CORES:
        for series, mode, tag in variants:
            if series == "pthreads" and p > 8:
                continue
            t, rt, t_wall = _run(series, mode, p, N_BASE, iters, driver)
            rows.append({"figure": "fig5_strong", "series": tag, "p": p,
                         "n": N_BASE, "driver": driver,
                         "t_iter_s": round(t, 6),
                         "speedup": round(t_ref / t, 3),
                         "net_bytes": rt.traffic.total_bytes,
                         "invalidations": rt.traffic.invalidations,
                         "diff_bytes": rt.traffic.diff_bytes,
                         "t_model_s": round(rt.time, 6),
                         "t_wall_s": round(t_wall, 4),
                         **traffic_fields(rt)})
    return rows


def weak(iters: int, driver: str):
    """n^2 scales with p: n = 4096 -> 65536 over p = 1 -> 256."""
    rows = []
    for p in CORES:
        n = int(N_BASE * p ** 0.5)
        n -= n % max(p, 64)                    # keep rows divisible
        for series, mode, tag in (
                ("pthreads", "reduction", "pthreads"),
                ("samhita", "lock", "samhita_lock"),
                ("samhita", "reduction", "samhita_reduction"),
                ("samhita_page", "lock", "samhita_page_lock"),
                ("samhita_page", "reduction", "samhita_page_reduction")):
            if series == "pthreads" and p > 8:
                continue
            t, rt, t_wall = _run(series, mode, p, n, iters, driver)
            rate = (n * n) / t
            rows.append({"figure": "fig6_weak", "series": tag, "p": p,
                         "n": n, "driver": driver,
                         "t_iter_s": round(t, 6),
                         "Mpoints_per_s": round(rate / 1e6, 2),
                         "net_bytes": rt.traffic.total_bytes,
                         "t_model_s": round(rt.time, 6),
                         "t_wall_s": round(t_wall, 4),
                         **traffic_fields(rt)})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--weak", action="store_true")
    ap.add_argument("--spill", action="store_true",
                    help="run only the capacity-pressure (fig5_spill) "
                         "points — the CI bench-smoke subset")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--driver", choices=["loop", "batched"],
                    default="batched",
                    help="SPMD phase driver: per-worker loop or phase_all")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write machine-readable rows here")
    args = ap.parse_args(argv)
    rows = []
    if args.all or not (args.weak or args.spill):
        rows += strong(args.iters, args.driver)
    if args.all or args.weak:
        rows += weak(args.iters, args.driver)
    if args.all or args.spill:
        rows += spill(max(2, args.iters // 2), args.driver)
    write_csv("jacobi" if args.driver == "batched"
              else f"jacobi_{args.driver}", rows)
    if args.json:
        write_bench_json(args.json, rows)
    print_rows(rows)
    return rows


if __name__ == "__main__":
    main()
