"""Protocol-kernel micro-benchmarks — fig12_kernels.

Times every protocol plane-sweep kernel tier head-to-head on the packed
``(W, window/32)`` uint32 planes the directory engine actually feeds
them: the boolean/SWAR ``numpy`` tier, the ``pallas`` interpret-mode
kernels (what CPU CI exercises; on a TPU the same kernels compile), and
the ``pallas-jit`` fused jitted tier, at windows {1k, 8k, 64k} pages x
worker counts {16, 64, 256}.  The committed walls are the evidence for
where each tier wins — the jit tier amortizes to a single XLA program
per shape, so it overtakes numpy as the plane grows.

One protocol-level point rides along: a halo phase program on the
batched driver with ``backend='pallas-jit'`` and an infinite cache,
where the ONLY kernel consumer is the barrier flush — so
``jit_dispatches`` must equal the barrier count exactly (one fused
device program per protocol phase, asserted in-bench).  Zero dispatches
anywhere would mean the jit tier silently fell back to numpy; the
``jit_*`` columns are gated field-for-field by ``benchmarks.compare``.

Wall times are report-only, like every ``t_wall_s``.  ``jit_compiles``
(first-seen shapes, mirroring jax's process-wide compile cache) is
deliberately NOT ``jit_``-prefixed in rows — it depends on what ran
earlier in the process, so it is reported as ``compiles`` untracked.

Timed reps are pinned (not ``--iters``-scaled) so the gated dispatch
counts are invocation-independent — ``--iters`` is accepted for harness
uniformity only, like ``kv_serving``.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import (jit_fields, make_rt, print_rows,
                               write_csv)
from repro.kernels import protocol_sweep as ps

WINDOWS = (1024, 8192, 65536)       # pages per worker plane
CORES = (16, 64, 256)
REPS = 3                            # timed reps (after 1 warmup), pinned
SEED = 13
N_PHASES = 6                        # protocol-level point: barrier count


def _plane(rng, W: int, window: int):
    """A packed dirty plane + eviction-style k vector at 35% density —
    the barrier-flush regime the directory engine feeds these kernels."""
    plane = rng.random((W, window)) < 0.35
    k = rng.integers(1, max(2, window // 3), W).astype(np.int64)
    return ps.pack_mask_rows(plane), k


def _geometry(rng, W: int, window: int):
    """Fused-chain geometry for one region: bases on a halo layout (every
    window overlaps its neighbours, so the coverage stab has real >=2
    spans), int32 with INT32_MAX padding exactly as the runtime packs."""
    stride = max(window // 2, 1)
    base = (np.arange(W, dtype=np.int64) * stride).astype(np.int32)
    sbs = np.sort(base).astype(np.int32)
    ses = np.sort(base + np.int32(window)).astype(np.int32)
    rowmask = np.ones((1, W), bool)
    return base[None], sbs[None], ses[None], rowmask


def _timed(fn) -> float:
    fn()                            # warmup (jit: compile; numpy: caches)
    t0 = time.perf_counter()
    for _ in range(REPS):
        fn()
    return (time.perf_counter() - t0) / REPS


def micro_rows():
    rows = []
    backends = (("numpy", "pallas", "pallas-jit") if ps.HAVE_PALLAS
                else ("numpy",))
    for window in WINDOWS:
        for W in CORES:
            rng = np.random.default_rng(SEED)
            bits, k = _plane(rng, W, window)
            base, sbs, ses, rowmask = _geometry(rng, W, window)
            pbits = bits[None]
            kernels = {
                "popcount": lambda b: ps.popcount_rows(
                    bits, backend=b, stats=st),
                "take_first_k": lambda b: ps.take_first_k(
                    bits, k, backend=b, stats=st),
                "kth_set_index": lambda b: ps.kth_set_index(
                    bits, k, backend=b, stats=st),
                "take_and_cut": lambda b: ps.take_and_cut(
                    bits, k, backend=b, stats=st),
            }
            for name, fn in kernels.items():
                for b in backends:
                    st = {}
                    wall = _timed(lambda: fn(b))
                    rows.append(_row(name, b, W, window, wall, st))
            # the fused flush chain has no interpret tier: it is either
            # the one jitted device program or the host oracle
            for b in ("numpy",) + (("pallas-jit",) if ps.HAVE_PALLAS
                                   else ()):
                st = {}
                if b == "pallas-jit":
                    wall = _timed(lambda: ps.phase_step(
                        pbits, base, rowmask, sbs, ses, stats=st))
                else:
                    wall = _timed(lambda: ps._phase_step_np(
                        pbits, base, rowmask, sbs, ses))
                rows.append(_row("phase_step", b, W, window, wall, st))
    return rows


def _row(kernel: str, backend: str, W: int, window: int, wall: float,
         st: dict):
    if backend == "pallas-jit":
        # warmup + pinned reps, every call one device dispatch — a zero
        # here is the silent-numpy-fallback signature the gate must catch
        assert st.get("jit_dispatches", 0) == REPS + 1, (kernel, W, st)
    return {"figure": "fig12_kernels", "series": f"{kernel}_{backend}",
            "p": W, "driver": f"{window // 1024}k", "window": window,
            "t_wall_s": round(wall, 7), **jit_fields(st)}


def protocol_rows():
    """One protocol-level point per worker count: a halo phase program on
    ``backend='pallas-jit'`` where the barrier flush is the only kernel
    consumer — ``jit_dispatches`` must equal the phase count exactly."""
    if not ps.HAVE_PALLAS:
        return []
    rows = []
    for W in CORES:
        rt = make_rt("samhita", W, backend="pallas-jit",
                     model_mechanism=False)
        ga = rt.alloc(W * 4096)
        ids = np.arange(W, dtype=np.int64)
        lo = np.maximum(ids * 4096 - 512, 0)
        hi = np.minimum(ids * 4096 + 4608, W * 4096)
        t0 = time.perf_counter()
        for _ in range(N_PHASES):
            rt.phase_all(writes=[(ga, lo, hi)])
            rt.barrier()
        wall = time.perf_counter() - t0
        # ONE fused device program per protocol phase — exactly, not
        # approximately: extra dispatches would mean the chain split,
        # zero that it silently fell back to numpy
        assert rt.stats["jit_dispatches"] == N_PHASES, (W, rt.stats)
        rows.append({"figure": "fig12_kernels",
                     "series": "phase_all_pallas-jit", "p": W,
                     "driver": "batched", "window": W * 4096 // 1024,
                     "t_wall_s": round(wall, 7),
                     "t_model_s": round(rt.time, 6), **jit_fields(rt)})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=8,
                    help="accepted for harness uniformity; timed reps are "
                         "pinned so the gated dispatch counters never "
                         "depend on the invocation")
    args = ap.parse_args(argv)
    del args
    rows = micro_rows() + protocol_rows()
    write_csv("kernels", rows)
    print_rows(rows)
    return rows


if __name__ == "__main__":
    main()
