"""Race detection — the detector bench (fig11_races).

The paper's programmability promise makes data races the user-facing
failure mode RegC must help catch; PR 8's ``detect_races=`` mode flags
them from the coherence metadata the directory already carries (see
"Race-detection contract" in ``src/repro/core/DIRECTORY.md``).  This
section measures what that costs and proves it costs nothing where it
must: ``apps.race_audit`` (clean bulk + striped-span work, plus a
deliberately unsynchronized W→R handoff and pairwise unlocked W/W
writes) runs every point TWICE — detector off, then on — at
W = 16/64/256 on the selected driver.

Rows carry the ON-run numbers plus the off-run wall time and the
relative ``detect_overhead`` column; the exact ``tr_*`` traffic fields
and the deterministic ``race_ww``/``race_rw`` counters are gated
field-for-field by ``benchmarks.compare`` (a silently-idle detector
fails the diff), and the bench itself asserts the pure-observer
contract per point: traffic field-for-field identical and modeled time
bit-equal between the two runs.
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import (SteadyState, make_rt, print_rows,
                               race_fields, span_fields, traffic_fields,
                               write_bench_json, write_csv)
from repro.dsm.apps import race_audit

N_BASE = 1 << 20
CORES = (16, 64, 256)
N_LOCKS = 4


def races(iters: int, driver: str, cores=CORES):
    rows = []
    for p in cores:
        for series in ("samhita", "samhita_page"):
            runs = {}
            for detect in (False, True):
                ss = SteadyState()
                t0 = time.perf_counter()
                rt = make_rt(series, p, detect_races=detect)
                race_audit(rt, N_BASE, iters, n_locks=N_LOCKS,
                           driver=driver, on_iter=ss)
                runs[detect] = (rt, time.perf_counter() - t0, ss)
            rt_on, wall_on, ss = runs[True]
            rt_off, wall_off, _ = runs[False]
            # the pure-observer contract, asserted per committed point:
            # detection changes no traffic field and no modeled second
            assert traffic_fields(rt_on) == traffic_fields(rt_off), (
                series, p, driver)
            assert rt_on.time == rt_off.time, (series, p, driver)
            assert rt_on.stats["race_ww"] > 0, (series, p, driver)
            assert rt_on.stats["race_rw"] > 0, (series, p, driver)
            overhead = ((wall_on - wall_off) / wall_off if wall_off
                        else 0.0)
            rows.append({"figure": "fig11_races", "series": series,
                         "p": p, "n": N_BASE, "driver": driver,
                         "t_iter_s": round(ss.per_iter(), 6),
                         "net_bytes": rt_on.traffic.total_bytes,
                         "t_model_s": round(rt_on.time, 6),
                         "t_wall_s": round(wall_on, 4),
                         "t_wall_off_s": round(wall_off, 4),
                         "detect_overhead": round(overhead, 3),
                         **traffic_fields(rt_on), **race_fields(rt_on),
                         **span_fields(rt_on)})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--driver", choices=["loop", "batched"],
                    default="batched",
                    help="SPMD phase + span driver: per-worker loop or "
                         "phase_all/span_all")
    ap.add_argument("--smoke", action="store_true",
                    help="quick local subset (W <= 64).  Missing the "
                         "committed W=256 keys routes the output to "
                         "*.partial.csv, so the committed artifacts stay "
                         "untouched")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write machine-readable rows here")
    args = ap.parse_args(argv)
    rows = races(args.iters, args.driver,
                 cores=CORES[:2] if args.smoke else CORES)
    write_csv("races" if args.driver == "batched"
              else f"races_{args.driver}", rows)
    if args.json:
        write_bench_json(args.json, rows)
    print_rows(rows)
    return rows


if __name__ == "__main__":
    main()
