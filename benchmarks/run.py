"""Benchmark orchestrator — one section per paper figure/table plus the
framework-level benches.  ``python -m benchmarks.run [--fast]``."""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer iterations / skip the slowest sections")
    args = ap.parse_args(argv)
    iters = 4 if args.fast else 8

    from benchmarks import (jacobi, molecular_dynamics, regc_training,
                            roofline, stream_triad)

    t0 = time.time()
    print("== STREAM TRIAD (paper Figs. 2/3/4) ==", flush=True)
    stream_triad.main(["--all", "--iters", str(iters)])

    print("== Jacobi (paper Figs. 5/6) ==", flush=True)
    jacobi.main(["--all", "--iters", str(iters)])

    print("== Molecular dynamics (paper Fig. 7) ==", flush=True)
    molecular_dynamics.main(["--iters", str(max(4, iters // 2))])

    print("== RegC training-layer sync policies (DESIGN.md 2.2) ==",
          flush=True)
    regc_training.main([])

    print("== Roofline summary (from dry-run artifacts) ==", flush=True)
    roofline.main(["--mesh", "16x16"])

    print(f"total bench time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
