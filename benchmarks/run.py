"""Benchmark orchestrator — one section per paper figure/table plus the
framework-level benches.  ``python -m benchmarks.run [--fast] [--json OUT]``.

Each section is wall-clock timed and failure-isolated (a section that
cannot run in this container — e.g. a jax-version mismatch — is recorded
as an error instead of aborting the harness), and the combined results are
written to a machine-readable ``BENCH_scale.json`` so future changes can
track the perf trajectory: per-point modeled time + exact traffic, per-
section wall seconds.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback
from pathlib import Path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer iterations / skip the slowest sections")
    ap.add_argument("--driver", choices=["loop", "batched", "both"],
                    default="batched",
                    help="SPMD phase driver for the protocol sections: "
                         "per-worker loop, worker-axis-batched phase_all, "
                         "or both (one timed pass per driver)")
    ap.add_argument("--sections", nargs="+", default=None, metavar="SUBSTR",
                    help="run only sections whose name or tags contain any "
                         "of these substrings; 'spill' focuses the protocol "
                         "sections on their capacity-pressure figures (the "
                         "CI bench-smoke subset)")
    ap.add_argument("--json", default="BENCH_scale.json", metavar="OUT",
                    help="write machine-readable results here "
                         "('' disables; default: %(default)s)")
    args = ap.parse_args(argv)
    iters = 4 if args.fast else 8
    drivers = (["loop", "batched"] if args.driver == "both"
               else [args.driver])
    # substring section filter; the 'spill' tag additionally swaps the
    # protocol sections' argv for their spill-only figure subsets, so a
    # focused CI run stays seconds while still crossing the exact-traffic
    # gate (partial runs land in *.partial.csv — the clobber guard)
    focus_spill = bool(args.sections) and any(
        "spill" in s for s in args.sections)

    def keep(name, tags=()):
        return args.sections is None or any(
            s in name or any(s in t for t in tags) for s in args.sections)

    from benchmarks import (availability, common, jacobi, kernels,
                            kv_serving, lock_contention,
                            molecular_dynamics, races, recovery,
                            regc_training, roofline, stream_triad)

    sections = []
    for d in drivers:
        tag = f"[{d}]" if len(drivers) > 1 else ""
        drv = ["--driver", d]
        st_args = ["--spill"] if focus_spill else ["--all"]
        ja_args = ["--spill"] if focus_spill else ["--all"]
        md_args = ["--spill"] if focus_spill else []
        sections += [
            (f"stream_triad (paper Figs. 2/3/4) {tag}",
             f"stream_triad{tag}", False, ("spill",),
             lambda drv=drv, a=st_args: stream_triad.main(
                 a + ["--iters", str(iters)] + drv)),
            (f"Jacobi (paper Figs. 5/6) {tag}", f"jacobi{tag}", False,
             ("spill",),
             lambda drv=drv, a=ja_args: jacobi.main(
                 a + ["--iters", str(iters)] + drv)),
            (f"Molecular dynamics (paper Fig. 7) {tag}",
             f"molecular_dynamics{tag}", False, ("spill",),
             lambda drv=drv, a=md_args: molecular_dynamics.main(
                 a + ["--iters", str(max(4, iters // 2))] + drv)),
            # a lock-focused run regenerates the exact committed point
            # set, so its CSVs would clobber the committed artifacts
            # (identical keys defeat write_csv's partial routing); the
            # CI bench_lock job redirects them with BENCH_OUT instead,
            # keeping ALL points under the compare gate
            (f"Lock contention (span engine) {tag}",
             f"lock_contention{tag}", False, ("lock",),
             lambda drv=drv: lock_contention.main(
                 ["--iters", str(iters)] + drv)),
            # like lock_contention, a focused run regenerates the exact
            # committed point set — the CI chaos job redirects its CSVs
            # with BENCH_OUT (see bench_lock)
            (f"Crash recovery (checkpoint/replay) {tag}",
             f"recovery{tag}", False, ("chaos",),
             lambda drv=drv: recovery.main(
                 ["--iters", str(max(3, iters // 2))] + drv)),
            # sharded multi-process runtime under injected shard death;
            # like recovery, a focused run regenerates the exact
            # committed point set — the CI cluster job redirects its
            # CSVs with BENCH_OUT (see bench_lock)
            (f"Availability (sharded cluster, process faults) {tag}",
             f"availability{tag}", False, ("cluster",),
             lambda drv=drv: availability.main(
                 ["--iters", str(max(3, iters // 2))] + drv)),
            # detector on/off overhead + pure-observer assertion; like
            # lock_contention, a focused run regenerates the exact
            # committed point set — the CI race job redirects its CSVs
            # with BENCH_OUT (see bench_lock)
            (f"Race detection (detector on/off) {tag}",
             f"races{tag}", False, ("race",),
             lambda drv=drv: races.main(
                 ["--iters", str(iters)] + drv)),
            # KV-cache serving adversary (inference traffic); the request
            # stream is a pure function of (W, seed) — independent of
            # --iters — so like lock_contention a focused run regenerates
            # the exact committed point set and the CI serve job
            # redirects its CSVs with BENCH_OUT (see bench_lock)
            (f"KV-cache serving (inference traffic) {tag}",
             f"kv_serving{tag}", False, ("serve",),
             lambda drv=drv: kv_serving.main(
                 ["--iters", str(iters)] + drv)),
        ]
    sections += [
        # protocol-kernel tiers head-to-head (fig12) + the one-dispatch-
        # per-phase protocol point; driver-independent, so it runs once.
        # A focused run regenerates the exact committed point set — the
        # CI kernels job redirects its CSV with BENCH_OUT (see bench_lock)
        ("Protocol kernels (numpy / pallas / pallas-jit tiers)",
         "kernels", False, ("kernels",),
         lambda: kernels.main(["--iters", str(iters)])),
        # jax-compile-bound (subprocess trainer), not a protocol section
        ("RegC training-layer sync policies (DESIGN.md 2.2)",
         "regc_training", True, (), lambda: regc_training.main([])),
        ("Roofline summary (from dry-run artifacts)", "roofline", False,
         (), lambda: roofline.main(["--mesh", "16x16"])),
    ]

    t0 = time.time()
    all_rows = []
    section_meta = {}
    failed = []
    for title, name, slow, tags, fn in sections:
        if not keep(name, tags):
            continue
        if slow and args.fast:
            print(f"== {title} == (skipped: --fast)", flush=True)
            section_meta[name] = {"wall_s": 0.0, "status": "skipped (--fast)"}
            continue
        print(f"== {title} ==", flush=True)
        s0 = time.time()
        try:
            rows = fn() or []
            status = "ok" if rows else "no data"
        except Exception as e:
            rows = []
            status = f"error: {type(e).__name__}: {e}"
            print(f"section {name} failed: {status}", flush=True)
            traceback.print_exc()
            failed.append(name)
        section_meta[name] = {"wall_s": round(time.time() - s0, 2),
                              "status": status}
        all_rows += rows

    total = time.time() - t0
    print(f"total bench time: {total:.1f}s")
    if args.json:
        prev = None
        if Path(args.json).exists():
            try:
                prev = json.loads(Path(args.json).read_text())
            except Exception:
                prev = None
        out_json = args.json
        if prev is not None:
            # same clobber guard as write_csv, gated purely on coverage:
            # any run missing points the existing file holds (a
            # --sections filter, a failure-isolated section, a --fast run
            # over a full-run baseline) must not replace the compare
            # gate's ground truth (BENCH_REFRESH=1 overrides for
            # deliberate removals)
            def keys(rows):
                return {(r.get("section"), r.get("protocol"), r.get("W"),
                         r.get("driver", "loop")) for r in rows}
            missing = (keys(prev.get("rows", []))
                       - keys(common.bench_json_rows(all_rows)))
            if missing and os.environ.get("BENCH_REFRESH") != "1":
                out_json = str(Path(args.json).with_suffix(".partial.json"))
                print(f"run: {args.json} covers {len(missing)} point(s) "
                      f"this partial run lacks; writing {out_json} "
                      "instead (BENCH_REFRESH=1 forces a refresh)")
        path = common.write_bench_json(
            out_json, all_rows,
            meta={"fast": bool(args.fast), "iters": iters,
                  "driver": args.driver,
                  "sections_filter": args.sections,
                  "total_wall_s": round(total, 2),
                  "sections": section_meta})
        print(f"wrote {path}")
        if args.fast and prev is not None and args.sections is None:
            # smoke-run the regression differ against the previous results
            # (report-only here; CI gates via `python -m benchmarks.compare`)
            from benchmarks import compare
            cur = json.loads(Path(path).read_text())
            print("== compare vs previous BENCH_scale.json ==")
            compare.report(prev, cur)
            # focused pass over the spill sections: the capacity-pressure
            # points are where batched eviction must stay traffic-exact
            print("== compare --sections spill ==")
            compare.report(prev, cur, sections=["spill"])
    if failed:
        # a failure-isolated section must still fail the invocation, or a
        # green-looking run can mask a dead section (the CI regression gate
        # would silently compare nothing for it)
        print(f"FAILED section(s): {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)
    return all_rows


if __name__ == "__main__":
    main()
