"""Benchmark orchestrator — one section per paper figure/table plus the
framework-level benches.  ``python -m benchmarks.run [--fast] [--json OUT]``.

Each section is wall-clock timed and failure-isolated (a section that
cannot run in this container — e.g. a jax-version mismatch — is recorded
as an error instead of aborting the harness), and the combined results are
written to a machine-readable ``BENCH_scale.json`` so future changes can
track the perf trajectory: per-point modeled time + exact traffic, per-
section wall seconds.
"""
from __future__ import annotations

import argparse
import time
import traceback


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer iterations / skip the slowest sections")
    ap.add_argument("--json", default="BENCH_scale.json", metavar="OUT",
                    help="write machine-readable results here "
                         "('' disables; default: %(default)s)")
    args = ap.parse_args(argv)
    iters = 4 if args.fast else 8

    from benchmarks import (common, jacobi, molecular_dynamics,
                            regc_training, roofline, stream_triad)

    sections = [
        ("stream_triad (paper Figs. 2/3/4)", "stream_triad", False,
         lambda: stream_triad.main(["--all", "--iters", str(iters)])),
        ("Jacobi (paper Figs. 5/6)", "jacobi", False,
         lambda: jacobi.main(["--all", "--iters", str(iters)])),
        ("Molecular dynamics (paper Fig. 7)", "molecular_dynamics", False,
         lambda: molecular_dynamics.main(
             ["--iters", str(max(4, iters // 2))])),
        # jax-compile-bound (subprocess trainer), not a protocol section
        ("RegC training-layer sync policies (DESIGN.md 2.2)",
         "regc_training", True, lambda: regc_training.main([])),
        ("Roofline summary (from dry-run artifacts)", "roofline", False,
         lambda: roofline.main(["--mesh", "16x16"])),
    ]

    t0 = time.time()
    all_rows = []
    section_meta = {}
    for title, name, slow, fn in sections:
        if slow and args.fast:
            print(f"== {title} == (skipped: --fast)", flush=True)
            section_meta[name] = {"wall_s": 0.0, "status": "skipped (--fast)"}
            continue
        print(f"== {title} ==", flush=True)
        s0 = time.time()
        try:
            rows = fn() or []
            status = "ok" if rows else "no data"
        except Exception as e:
            rows = []
            status = f"error: {type(e).__name__}: {e}"
            print(f"section {name} failed: {status}", flush=True)
            traceback.print_exc()
        section_meta[name] = {"wall_s": round(time.time() - s0, 2),
                              "status": status}
        all_rows += rows

    total = time.time() - t0
    print(f"total bench time: {total:.1f}s")
    if args.json:
        path = common.write_bench_json(
            args.json, all_rows,
            meta={"fast": bool(args.fast), "iters": iters,
                  "total_wall_s": round(total, 2),
                  "sections": section_meta})
        print(f"wrote {path}")
    return all_rows


if __name__ == "__main__":
    main()
