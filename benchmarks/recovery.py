"""Crash recovery — the fault-tolerance bench (fig9_recovery).

Measures what the barrier-consistent checkpoint substrate costs and what
a crash costs to erase: per-checkpoint snapshot+save wall time, restore
wall time, checkpoint size on disk, and the recovery run's replayed-event
fraction, against the uninjected wall — at W = 16/64/256, both samhita
series, on the selected driver, with deterministic message loss
(``ChaosNet``) and barrier straggler monitoring always on.

Every row carries the exact ``tr_*`` traffic fields plus the
``chaos_*``/``straggler_*`` counters (all gated field-for-field by
``benchmarks.compare``): the committed results PROVE the loss/retry and
straggler paths fired, and the bench itself asserts the recovered run is
bit-equal to the uninjected one — the exactness bar as a benchmark
invariant, not just a test.
"""
from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import (chaos_fields, make_rt, print_rows,
                               traffic_fields, write_bench_json, write_csv)
from repro.dsm.costmodel import ChaosNet
from repro.ft import (ChaosHarness, FailureInjector, StragglerMonitor,
                      assert_bit_equal, load_runtime, run_uninjected,
                      save_runtime)

PAGE_WORDS = 1024
PAGES_PER_WORKER = 16
CORES = (16, 64, 256)
DROP_RATE = 0.05
CHAOS_SEED = 11


def gen_program(W: int, n_words: int, iters: int):
    """Deterministic phase program: per iteration one bulk phase (block
    reads + rotating writes — invalidation traffic every pass), one
    batched span pass on striped locks (grant chains through the span
    engine), and a barrier (checkpoint cut + straggler observation)."""
    ids = np.arange(W, dtype=np.int64)
    chunk = n_words // W
    prog = []
    for it in range(iters):
        r = (ids + it) % W
        reads = [(0, ids * chunk, np.minimum((ids + 1) * chunk, n_words))]
        writes = [(0, r * chunk,
                   np.where(r == W - 1, n_words, (r + 1) * chunk))]
        # worker 0 drags a heavy modeled compute tail every phase: a
        # deterministic straggler the barrier monitor must flag
        # (visible in the committed straggler_flags counters)
        flops = np.zeros(W)
        flops[0] = 5e6
        prog.append(("phase", reads, writes, flops))
        lo = np.full(W, (it * 7) % max(n_words - 8, 1), np.int64)
        prog.append(("span_phase", ids % 4, [(0, lo, lo + 8)],
                     [(0, lo.copy(), lo.copy() + 8)]))
        prog.append(("barrier",))
    return prog


def apply_event(rt, ev, gas, driver: str):
    """Program executor for both drivers (the bench-side analogue of the
    trace-fuzz executor; ``ft.harness_ticks`` decides who calls
    ``chaos_tick``)."""
    W = rt.W
    if ev[0] == "phase":
        _, reads, writes, flops = ev
        r = [(gas[g], lo, hi) for g, lo, hi in reads]
        wr = [(gas[g], lo, hi) for g, lo, hi in writes]
        if driver == "batched":
            rt.phase_all(reads=r, writes=wr, flops=flops)
            return
        for w in range(W):
            rt.phase(w, reads=[(ga, int(lo[w]), int(hi[w]))
                               for ga, lo, hi in r],
                     writes=[(ga, int(lo[w]), int(hi[w]))
                             for ga, lo, hi in wr],
                     flops=float(flops[w]))
    elif ev[0] == "span_phase":
        _, locks, reads, writes = ev
        r = [(gas[g], lo, hi) for g, lo, hi in reads]
        wr = [(gas[g], lo, hi) for g, lo, hi in writes]
        if driver == "batched":
            rt.span_all(None, locks, reads=r, writes=wr)
            return
        for w in range(W):
            with rt.span(w, int(locks[w])):
                for ga, lo, hi in r:
                    rt.read(w, ga, int(lo[w]), int(hi[w]))
                for ga, lo, hi in wr:
                    rt.write(w, ga, int(lo[w]), int(hi[w]))
    else:
        rt.barrier()


def _dir_bytes(d: Path) -> int:
    return sum(f.stat().st_size for f in Path(d).rglob("*") if f.is_file())


def recovery(iters: int, driver: str, cores=CORES):
    rows = []
    for p in cores:
        n_words = PAGE_WORDS * PAGES_PER_WORKER * p
        for series in ("samhita", "samhita_page"):
            def mk():
                return make_rt(
                    series, p, page_words=PAGE_WORDS,
                    chaos=ChaosNet(seed=CHAOS_SEED, drop_rate=DROP_RATE),
                    straggler=StragglerMonitor(p, window=4, patience=2))

            prog = gen_program(p, n_words, iters)
            t0 = time.perf_counter()
            base = run_uninjected(mk, [n_words], driver, prog, apply_event)
            t_wall = time.perf_counter() - t0
            with tempfile.TemporaryDirectory() as td:
                # checkpoint + restore microcosts on the END state (the
                # largest the directories get)
                t0 = time.perf_counter()
                save_runtime(base, td, 0)
                t_ckpt = time.perf_counter() - t0
                ckpt_bytes = _dir_bytes(Path(td) / "step_000000000")
                t0 = time.perf_counter()
                restored = load_runtime(td, 0)
                t_restore = time.perf_counter() - t0
                np.testing.assert_array_equal(restored.clock, base.clock)
            # crash worker p//2 at a mid-run BARRIER tick (each iteration
            # is 3 events, so tick 3*(iters//2) is a barrier): the whole
            # iteration since the last checkpoint re-executes, keeping
            # replayed_events > 0 in the committed rows.  Recovery must
            # land bit-equal with the uninjected run.
            inj = FailureInjector(
                at_steps=[(3 * max(1, iters // 2), p // 2)])
            with tempfile.TemporaryDirectory() as td:
                t0 = time.perf_counter()
                rec, rep = ChaosHarness(mk, [n_words], driver, td,
                                        apply_event, injector=inj
                                        ).run(prog)
                t_recovery = time.perf_counter() - t0
            assert rep.n_crashes == 1, rep
            assert_bit_equal(rec, base, (series, p, driver))
            rows.append({
                "figure": "fig9_recovery", "series": series, "p": p,
                "n": n_words, "driver": driver,
                "t_model_s": round(base.time, 6),
                "t_wall_s": round(t_wall, 4),
                "t_ckpt_s": round(t_ckpt, 4),
                "t_restore_s": round(t_restore, 4),
                "t_recovery_wall_s": round(t_recovery, 4),
                "ckpt_bytes": ckpt_bytes,
                "n_events": rep.n_events,
                "n_checkpoints": rep.n_checkpoints,
                "n_crashes": rep.n_crashes,
                "replayed_events": rep.n_replayed_events,
                "net_bytes": base.traffic.total_bytes,
                **traffic_fields(base), **chaos_fields(base)})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=6,
                    help="barrier-delimited iterations per point")
    ap.add_argument("--driver", choices=["loop", "batched"],
                    default="batched")
    ap.add_argument("--smoke", action="store_true",
                    help="quick local subset (W <= 64).  Missing the "
                         "committed W=256 keys routes the output to "
                         "*.partial.csv, so the committed artifacts stay "
                         "untouched")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write machine-readable rows here")
    args = ap.parse_args(argv)
    rows = recovery(args.iters, args.driver,
                    cores=CORES[:2] if args.smoke else CORES)
    write_csv("recovery" if args.driver == "batched"
              else f"recovery_{args.driver}", rows)
    if args.json:
        write_bench_json(args.json, rows)
    print_rows(rows)
    return rows


if __name__ == "__main__":
    main()
